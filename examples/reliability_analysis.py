"""Cluster-operator workflow: simulate an RSC-like cluster, then run the
paper's full §III analysis — status mix, attribution, MTTF curve + CIs,
ETTR, goodput cascades — and §IV mitigations (lemon detection).

  PYTHONPATH=src python examples/reliability_analysis.py [--days 8]
  PYTHONPATH=src python examples/reliability_analysis.py --mitigations
"""
import argparse
import sys

sys.path.insert(0, "src")

import numpy as np

from repro.cluster import analysis
from repro.cluster.scheduler import ClusterSim
from repro.cluster.workload import ClusterSpec
from repro.core import mttf_model
from repro.core.lemon import LemonDetector, LemonThresholds


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--days", type=float, default=8.0)
    ap.add_argument("--nodes", type=int, default=400)
    ap.add_argument("--mitigations", action="store_true",
                    help="run a mitigation-lab what-if: lemon eviction as a "
                         "live scheduler policy (repro.mitigations)")
    args = ap.parse_args()

    spec = ClusterSpec("RSC-1", n_nodes=args.nodes,
                       jobs_per_day=args.nodes * 3.6,
                       target_utilization=0.83, r_f=6.5e-3)
    print(f"simulating {spec.name}: {spec.n_nodes} nodes, "
          f"{args.days:.0f} days, r_f={spec.r_f*1000:.2f}/1000 node-days...")
    sim = ClusterSim(spec, horizon_days=args.days, seed=0)
    sim.run()
    print(f"  {len(sim.records)} job attempts, {len(sim.fault_log)} faults, "
          f"{len(sim.drain_log)} node drains\n")

    print("== Figure 3: job status mix ==")
    sb = analysis.status_breakdown(sim.records)
    for k, v in sorted(sb["jobs"].items(), key=lambda kv: -kv[1]):
        print(f"  {k:14s} {v:6.1%} of jobs, "
              f"{sb['gpu_time'].get(k, 0):6.1%} of GPU time")
    imp = analysis.hw_impact(sim.records)
    print(f"  HW-attributed: {imp['hw_job_fraction']:.2%} of jobs, "
          f"{imp['hw_runtime_fraction']:.1%} of runtime (Obs 4)\n")

    print("== Figure 7: MTTF by job size (90% Gamma CIs) ==")
    rf = mttf_model.fit_r_f(sim.records, min_gpus=64) or spec.r_f
    for p in mttf_model.empirical_mttf_curve(sim.records):
        if p.n_failures >= 1 and p.n_gpus >= 64:
            th = mttf_model.projected_mttf_hours(p.n_gpus, rf)
            print(f"  {p.n_gpus:5d} GPUs: {p.mttf_hours:8.1f} h "
                  f"[{p.ci_lo_hours:.1f}, {p.ci_hi_hours:.1f}] "
                  f"(n={p.n_failures}, theory {th:.1f} h)")
    print(f"  fitted r_f = {rf*1000:.2f}/1000 node-days")
    print(f"  projections: 16k GPUs -> "
          f"{mttf_model.projected_mttf_hours(16384, rf):.1f} h, "
          f"131k GPUs -> {mttf_model.projected_mttf_hours(131072, rf):.2f} h\n")

    print("== Figure 8: goodput loss ==")
    casc = analysis.preemption_cascades(sim.records)
    print(f"  failure loss:    {casc['failure_loss_gpu_h']:.0f} GPU-h")
    print(f"  preemption loss: {casc['preemption_loss_gpu_h']:.0f} GPU-h "
          f"({casc['second_order_fraction']:.0%} second-order)\n")

    print("== §IV-A: lemon detection ==")
    det = LemonDetector(LemonThresholds(
        xid_cnt=2, tickets=1, out_count=2, multi_node_node_fails=1,
        single_node_node_fails=1, min_signals=2))
    mit = ClusterSim(spec, horizon_days=args.days, seed=0,
                     enable_lemon_detection=True,
                     lemon_scan_period_days=1.0, lemon_detector=det)
    mit.run()
    f0 = analysis.large_job_failure_rate(sim.records, 128)
    f1 = analysis.large_job_failure_rate(mit.records, 128)
    print(f"  large-job (128+) failure rate: {f0:.1%} -> {f1:.1%} "
          f"with {len(mit.lemon_removal_log)} lemons removed "
          f"(paper: 14% -> 4%)")

    if args.mitigations:
        from repro.mitigations import make_policy
        from repro.mitigations.sweep import run_cell

        print("\n== Mitigation lab: lemon-eviction what-if ==")
        pol = make_policy("lemon_eviction", seed=0)
        what_if = ClusterSim(spec, horizon_days=args.days, seed=0,
                             policy=pol)
        what_if.run()
        w0 = analysis.large_job_failure_rate(sim.records, 128)
        w1 = analysis.large_job_failure_rate(what_if.records, 128)
        print(f"  policy path: {len(pol.evictions)} evictions, large-job "
              f"failure rate {w0:.1%} -> {w1:.1%}")
        n_gpus = spec.n_gpus
        base = run_cell("baseline", n_gpus, seed=0, horizon_days=args.days)
        mitc = run_cell("lemon_eviction", n_gpus, seed=0,
                        horizon_days=args.days)
        print(f"  sweep cell @ {n_gpus} GPUs: ETTR {base.ettr_sim:.3f} -> "
              f"{mitc.ettr_sim:.3f} (model {base.ettr_model:.3f}), "
              f"goodput {base.goodput:.3f} -> {mitc.goodput:.3f}")
        print("  full grid: PYTHONPATH=src python -m repro.mitigations.sweep")


if __name__ == "__main__":
    main()
