"""Cluster-operator workflow: simulate an RSC-like cluster (recording its
trace), then run the paper's full §III analysis — status mix, attribution,
MTTF curve + CIs, ETTR, goodput cascades — and §IV mitigations (lemon
detection).  With --trace, skip the simulation and run the full report on
a saved (.npz/.jsonl) or ingested (Philly-style .csv) trace instead.

  PYTHONPATH=src python examples/reliability_analysis.py [--days 8]
  PYTHONPATH=src python examples/reliability_analysis.py --mitigations
  PYTHONPATH=src python examples/reliability_analysis.py --save-trace run.npz
  PYTHONPATH=src python examples/reliability_analysis.py --trace run.npz
  PYTHONPATH=src python examples/reliability_analysis.py --trace jobs.csv
"""
import argparse
import sys

sys.path.insert(0, "src")

import numpy as np

from repro.cluster import analysis
from repro.cluster.scheduler import ClusterSim
from repro.cluster.workload import ClusterSpec
from repro.core import mttf_model
from repro.core.lemon import LemonDetector, LemonThresholds


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--days", type=float, default=8.0)
    ap.add_argument("--nodes", type=int, default=400)
    ap.add_argument("--mitigations", action="store_true",
                    help="run a mitigation-lab what-if: lemon eviction as a "
                         "live scheduler policy (repro.mitigations)")
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="skip the simulation: run the full Fig. 3-9 "
                         "report on a saved (.npz/.jsonl) or ingested "
                         "(Philly-style .csv) trace")
    ap.add_argument("--save-trace", default=None, metavar="PATH",
                    help="save the simulated trace (.npz or .jsonl) for "
                         "later re-analysis")
    args = ap.parse_args()
    if args.save_trace and not args.save_trace.endswith((".npz", ".jsonl")):
        ap.error(f"--save-trace {args.save_trace!r}: use a .npz or .jsonl "
                 "suffix (checked up front so a long run is not wasted)")
    if args.trace and args.mitigations:
        ap.error("--mitigations runs live scheduler policies and needs a "
                 "simulation; it cannot be combined with --trace")

    if args.trace:
        from repro.trace.report import compute_report, load_any, print_report

        trace = load_any(args.trace)
        print(f"report from trace {args.trace} "
              f"(source: {trace.meta.get('source', '?')})")
        if args.save_trace:
            from repro.trace import io as trace_io

            trace_io.save(trace, args.save_trace)
            print(f"trace re-saved to {args.save_trace}")
        print_report(compute_report(trace))
        return

    from repro.trace import TraceRecorder

    spec = ClusterSpec("RSC-1", n_nodes=args.nodes,
                       jobs_per_day=args.nodes * 3.6,
                       target_utilization=0.83, r_f=6.5e-3)
    print(f"simulating {spec.name}: {spec.n_nodes} nodes, "
          f"{args.days:.0f} days, r_f={spec.r_f*1000:.2f}/1000 node-days...")
    recorder = TraceRecorder()
    sim = ClusterSim(spec, horizon_days=args.days, seed=0, recorder=recorder)
    sim.run()
    # record trace -> analyze trace: all §III metrics below consume the
    # trace object, not in-engine counters
    trace = recorder.finalize(sim)
    print(f"  {trace.n_rows('jobs')} job attempts, "
          f"{trace.n_rows('faults')} faults, "
          f"{len(sim.drain_log)} node drains\n")
    if args.save_trace:
        from repro.trace import io as trace_io

        trace_io.save(trace, args.save_trace)
        print(f"  trace saved to {args.save_trace} "
              f"(re-analyze: python -m repro.trace.report "
              f"{args.save_trace})\n")

    print("== Figure 3: job status mix ==")
    sb = analysis.status_breakdown(trace)
    for k, v in sorted(sb["jobs"].items(), key=lambda kv: -kv[1]):
        print(f"  {k:14s} {v:6.1%} of jobs, "
              f"{sb['gpu_time'].get(k, 0):6.1%} of GPU time")
    imp = analysis.hw_impact(trace)
    print(f"  HW-attributed: {imp['hw_job_fraction']:.2%} of jobs, "
          f"{imp['hw_runtime_fraction']:.1%} of runtime (Obs 4)\n")

    print("== Figure 7: MTTF by job size (90% Gamma CIs) ==")
    records = trace.job_records()
    rf = mttf_model.fit_r_f(records, min_gpus=64) or spec.r_f
    for p in mttf_model.empirical_mttf_curve(records):
        if p.n_failures >= 1 and p.n_gpus >= 64:
            th = mttf_model.projected_mttf_hours(p.n_gpus, rf)
            print(f"  {p.n_gpus:5d} GPUs: {p.mttf_hours:8.1f} h "
                  f"[{p.ci_lo_hours:.1f}, {p.ci_hi_hours:.1f}] "
                  f"(n={p.n_failures}, theory {th:.1f} h)")
    print(f"  fitted r_f = {rf*1000:.2f}/1000 node-days")
    print(f"  projections: 16k GPUs -> "
          f"{mttf_model.projected_mttf_hours(16384, rf):.1f} h, "
          f"131k GPUs -> {mttf_model.projected_mttf_hours(131072, rf):.2f} h\n")

    print("== Figure 8: goodput loss ==")
    casc = analysis.preemption_cascades(trace)
    print(f"  failure loss:    {casc['failure_loss_gpu_h']:.0f} GPU-h")
    print(f"  preemption loss: {casc['preemption_loss_gpu_h']:.0f} GPU-h "
          f"({casc['second_order_fraction']:.0%} second-order)\n")

    print("== §IV-A: lemon detection ==")
    det = LemonDetector(LemonThresholds(
        xid_cnt=2, tickets=1, out_count=2, multi_node_node_fails=1,
        single_node_node_fails=1, min_signals=2))
    mit = ClusterSim(spec, horizon_days=args.days, seed=0,
                     enable_lemon_detection=True,
                     lemon_scan_period_days=1.0, lemon_detector=det)
    mit.run()
    f0 = analysis.large_job_failure_rate(trace, 128)
    f1 = analysis.large_job_failure_rate(mit, 128)
    print(f"  large-job (128+) failure rate: {f0:.1%} -> {f1:.1%} "
          f"with {len(mit.lemon_removal_log)} lemons removed "
          f"(paper: 14% -> 4%)")

    if args.mitigations:
        from repro.mitigations import make_policy
        from repro.mitigations.sweep import run_cell

        print("\n== Mitigation lab: lemon-eviction what-if ==")
        pol = make_policy("lemon_eviction", seed=0)
        what_if = ClusterSim(spec, horizon_days=args.days, seed=0,
                             policy=pol)
        what_if.run()
        w1 = analysis.large_job_failure_rate(what_if, 128)
        print(f"  policy path: {len(pol.evictions)} evictions, large-job "
              f"failure rate {f0:.1%} -> {w1:.1%}")
        n_gpus = spec.n_gpus
        base = run_cell("baseline", n_gpus, seed=0, horizon_days=args.days)
        mitc = run_cell("lemon_eviction", n_gpus, seed=0,
                        horizon_days=args.days)
        print(f"  sweep cell @ {n_gpus} GPUs: ETTR {base.ettr_sim:.3f} -> "
              f"{mitc.ettr_sim:.3f} (model {base.ettr_model:.3f}), "
              f"goodput {base.goodput:.3f} -> {mitc.goodput:.3f}")
        print("  full grid: PYTHONPATH=src python -m repro.mitigations.sweep")


if __name__ == "__main__":
    main()
