"""§Dry-run / §Roofline: aggregate the 40-cell x 2-mesh sweep results.

Reads results/dryrun/*.json (produced by scripts/run_dryrun_sweep.sh) and
prints the per-cell roofline table; also writes results/roofline.md for
EXPERIMENTS.md."""
import glob
import json
import os
import pathlib

from benchmarks.common import benchmark

COLS = ("compute_s", "memory_s", "collective_s")


@benchmark("roofline_table")
def run(rep):
    root = pathlib.Path(__file__).resolve().parent.parent
    files = sorted(glob.glob(str(root / "results" / "dryrun" / "*.json")))
    if not files:
        rep.add("status", "no dry-run results found; run "
                "scripts/run_dryrun_sweep.sh first")
        return
    recs = [json.load(open(f)) for f in files]
    ok = [r for r in recs if r["status"] == "ok"]
    skipped = [r for r in recs if r["status"] == "skipped_full_attention"]
    errors = [r for r in recs if r["status"] == "error"]
    rep.add("cells_total", len(recs))
    rep.add("cells_ok", len(ok))
    rep.add("cells_skipped_long500k", len(skipped))
    rep.add("cells_error", len(errors))
    rep.check("all 80 cells accounted (40 x 2 meshes)", len(recs) == 80)
    rep.check("every cell compiles or is a documented skip",
              len(errors) == 0)
    fits = [r for r in ok if r.get("fits_hbm")]
    rep.add("cells_fit_16GiB_HBM", f"{len(fits)}/{len(ok)}")

    lines = ["| arch | shape | mesh | dominant | compute_s | memory_s | "
             "collective_s | roofline_frac | useful_flops | mem GiB | fits | n_micro |",
             "|---|---|---|---|---|---|---|---|---|---|---|---|"]
    worst = []
    for r in sorted(ok, key=lambda r: (r["arch"], r["shape"], r["mesh"])):
        rl = r["roofline"]
        mem = r["memory"]["peak_device_bytes"] / 2**30
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | {rl['dominant']} "
            f"| {rl['compute_s']:.3g} | {rl['memory_s']:.3g} "
            f"| {rl['collective_s']:.3g} | {rl['roofline_fraction']:.4f} "
            f"| {rl['useful_flops_ratio']:.3f} | {mem:.2f} "
            f"| {'y' if r.get('fits_hbm') else 'N'} "
            f"| {r.get('n_microbatches', 1)} |")
        worst.append((rl["roofline_fraction"], r["arch"], r["shape"],
                      r["mesh"], rl["dominant"]))
    for r in sorted(skipped, key=lambda r: (r["arch"], r["mesh"])):
        lines.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} "
                     f"| SKIPPED (pure full attention) | | | | | | | | |")
    out = root / "results" / "roofline.md"
    out.write_text("\n".join(lines) + "\n")
    rep.add("table_written", str(out))

    worst.sort()
    train = [w for w in worst if w[2] == "train_4k"]
    if train:
        best = max(train)
        rep.add("best_train_roofline_frac",
                f"{best[0]:.4f} ({best[1]} {best[3]})")
    coll_bound = [w for w in worst if w[4] == "collective"]
    rep.add("collective_bound_cells", len(coll_bound))
    mem_bound = [w for w in worst if w[4] == "memory"]
    rep.add("memory_bound_cells", len(mem_bound))
    rep.add("compute_bound_cells",
            len([w for w in worst if w[4] == "compute"]))
