"""Figure 8 / Observation 9: goodput loss from failures + second-order
preemption cascades, by job size."""
from benchmarks.common import benchmark, get_sim
from repro.cluster import analysis


@benchmark("fig8_goodput_loss")
def run(rep):
    for cluster in ("RSC-1", "RSC-2"):
        sim = get_sim(cluster, days=12.0)
        by_size = analysis.goodput_loss_by_size(sim.records)
        for bucket, loss in by_size.items():
            if loss["failure_gpu_h"] or loss["preemption_gpu_h"]:
                rep.add(f"{cluster}.loss[{bucket}]",
                        f"fail={loss['failure_gpu_h']:.0f} "
                        f"preempt={loss['preemption_gpu_h']:.0f} GPU-h")
        casc = analysis.preemption_cascades(sim.records)
        rep.add(f"{cluster}.second_order_fraction",
                round(casc["second_order_fraction"], 3),
                "paper RSC-1: 0.16")
    s1 = get_sim("RSC-1", days=12.0)
    s2 = get_sim("RSC-2", days=12.0)
    c1 = analysis.preemption_cascades(s1.records)
    c2 = analysis.preemption_cascades(s2.records)
    rep.check("Obs 9: second-order preemptions are a real loss channel",
              c1["second_order_fraction"] > 0.0 or
              c2["second_order_fraction"] > 0.0)
    # large jobs dominate first-order loss on RSC-1
    by1 = analysis.goodput_loss_by_size(s1.records)
    big = sum(v["failure_gpu_h"] for k, v in by1.items()
              if int(k.split("-")[0]) >= 257)
    small = sum(v["failure_gpu_h"] for k, v in by1.items()
                if int(k.split("-")[1]) <= 256)
    rep.add("RSC-1.failure_loss_big_vs_small",
            f"{big:.0f} vs {small:.0f} GPU-h")
    rep.check("RSC-1: most failure loss from large jobs (Fig 8)",
              big >= small or big + small == 0)
