"""stat_bench: throughput of the statistical backends (cells/sec).

Times ``repro.core.backend.batch_bands`` on both backends over two
grids and gates the tentpole perf claims of the backend-dispatch seam:

- **analytic grid** (4 policies x 3 scales x many seeds, per-cell r_f
  jitter): closed-form ETTR / E[failures] / MTTF band math.  The numpy
  path is a per-cell Python loop over the public scalar functions; the
  JAX_VMAP path evaluates the whole grid in one jitted call.  Claim:
  >= 50x cells/sec.
- **MC grid** (16 seeds x 3 scales, Monte-Carlo run draws per cell):
  the masked-``while_loop`` MC kernel.  RNG-element-bound on CPU, so
  the speedup is modest (claim: >= 2x) — the structural claim gated
  here is *one compiled call* for the entire seed x scale grid,
  ``include_mc=True``.

Rows ending in ``cells_per_sec`` feed the ``--compare`` throughput
regression gate.  When jax is unavailable the numpy rows still run and
the jax checks report WARN (benchmarks are reports, tests are gates).
"""
import time

import numpy as np

from benchmarks import common
from benchmarks.common import benchmark

SCALES = (1024, 4096, 16384)
POLICIES_SPEC = (
    ("hourly", dict()),                               # dt_cp_s=3600 default
    ("daly-young", dict(dt_cp_s=0.0)),                # optimal-interval limit
    ("fast-cp", dict(dt_cp_s=0.0, w_cp_s=30.0)),      # cheap checkpoints
    ("queued", dict(q_s=1800.0)),                     # requeue penalty
)


def _policies():
    from repro.core.backend import PolicyCell

    return tuple(PolicyCell(name=n, **kw) for n, kw in POLICIES_SPEC)


def _min_wall(fn, repeats: int) -> float:
    """Min wall over ``repeats`` calls (min is the standard noise floor
    for short CPU timings)."""
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


@benchmark("stat_bench")
def run(rep):
    from repro.core.backend import (BandGrid, batch_bands, jax_available)

    has_jax = jax_available()
    if common.QUICK:
        n_seeds, mc_seeds, mc_runs, repeats = 256, 8, 64, 2
    else:
        n_seeds, mc_seeds, mc_runs, repeats = 1024, 16, 256, 3

    # -- analytic band grid: closed-form math over a big seed ensemble --
    grid = BandGrid(
        gpus=SCALES, seeds=tuple(range(n_seeds)), policies=_policies(),
        r_f=np.linspace(4e-3, 9e-3, n_seeds))
    rep.label("analytic_grid",
              f"{len(grid.policies)}pol_x_{len(SCALES)}scale_x_{n_seeds}seed")
    rep.add("analytic_grid_cells", grid.n_cells)

    t_np = _min_wall(lambda: batch_bands(grid, backend="numpy"), repeats)
    np_cps = grid.n_cells / t_np
    rep.add("analytic_numpy_cells_per_sec", round(np_cps),
            f"{t_np * 1e3:.1f} ms/grid, per-cell Python loop")

    res_np = batch_bands(grid, backend="numpy")
    if has_jax:
        t0 = time.perf_counter()
        res_jx = batch_bands(grid, backend="jax_vmap")   # compile + run
        t_cold = time.perf_counter() - t0
        t_jx = _min_wall(lambda: batch_bands(grid, backend="jax_vmap"),
                         max(repeats, 5))
        jx_cps = grid.n_cells / t_jx
        rep.add("analytic_jax_cells_per_sec", round(jx_cps),
                f"{t_jx * 1e3:.2f} ms/grid warm "
                f"({t_cold * 1e3:.0f} ms incl. compile), 1 jitted call")
        speedup = jx_cps / np_cps
        rep.add("analytic_speedup_x", round(speedup, 1),
                "jax_vmap vs numpy cells/sec")
        rep.check("JAX_VMAP analytic band grid >= 50x numpy cells/sec",
                  speedup >= 50.0, f"{speedup:.0f}x on {grid.n_cells} cells")
        rel = np.max(np.abs(res_jx.ettr - res_np.ettr)
                     / np.maximum(np.abs(res_np.ettr), 1e-6))
        rep.check("backend ETTR parity on the analytic grid (rel < 1e-4)",
                  bool(rel < 1e-4), f"max rel diff {rel:.2e}")
    else:
        rep.check("jax backend available for the analytic speedup claim",
                  False, "jax import failed; numpy rows only")

    # -- MC grid: per-cell Monte-Carlo attempt chains, one compiled call --
    mc_grid = BandGrid(
        gpus=SCALES, seeds=tuple(range(mc_seeds)),
        r_f=np.linspace(5e-3, 8e-3, mc_seeds), n_runs=mc_runs)
    rep.label("mc_grid",
              f"{mc_seeds}seed_x_{len(SCALES)}scale_{mc_runs}runs")
    rep.add("mc_grid_cells", mc_grid.n_cells)

    t_np = _min_wall(
        lambda: batch_bands(mc_grid, backend="numpy", include_mc=True),
        repeats)
    np_cps = mc_grid.n_cells / t_np
    rep.add("mc_numpy_cells_per_sec", round(np_cps),
            f"{t_np * 1e3:.1f} ms/grid, n_runs={mc_runs}")

    if has_jax:
        res_mc = batch_bands(mc_grid, backend="jax_vmap", include_mc=True)
        rep.check("MC+analytic seed x scale grid evaluated in one "
                  "compiled call",
                  res_mc.n_compiled_calls == 1,
                  f"{mc_grid.n_cells} cells, "
                  f"{res_mc.n_compiled_calls} compiled call(s)")
        t_jx = _min_wall(
            lambda: batch_bands(mc_grid, backend="jax_vmap",
                                include_mc=True),
            max(repeats, 5))
        jx_cps = mc_grid.n_cells / t_jx
        rep.add("mc_jax_cells_per_sec", round(jx_cps),
                f"{t_jx * 1e3:.1f} ms/grid warm")
        speedup = jx_cps / np_cps
        rep.add("mc_speedup_x", round(speedup, 1),
                "RNG-element-bound on CPU; structural claim is the "
                "single compiled call")
        rep.check("JAX_VMAP MC grid >= 2x numpy cells/sec",
                  speedup >= 2.0, f"{speedup:.1f}x")
        res_mc_np = batch_bands(mc_grid, backend="numpy", include_mc=True)
        mc_diff = float(np.max(np.abs(res_mc.mc_ettr_mean
                                      - res_mc_np.mc_ettr_mean)))
        rep.check("MC ETTR means statistically consistent across "
                  "backends (< 0.05)",
                  mc_diff < 0.05, f"max |diff| {mc_diff:.4f} "
                  "(different RNGs — distributional, not bitwise)")
    else:
        rep.check("jax backend available for the one-compiled-call claim",
                  False, "jax import failed; numpy rows only")
