"""Cell-cache warm-grid speedup: cold ensemble grids vs fully-warm repeats.

The paper's headline artifacts (MTTF-vs-scale fits §V, ETTR efficacy
bands Fig. 9) are ensembles of deterministic replay cells, and across
grids and invocations the same (scenario, scale, seed) cells recur.
``repro.ensemble.cellcache`` memoizes scored cells content-addressed by
engine version + canonical cell config; this benchmark prices the two
warm paths on the ISSUE-4 acceptance grid (16 seeds x {1024, 4096,
16384} GPUs x 8 days):

  * ``warm_cells_per_sec`` — the gated throughput row: a fully-warm
    repeat of the grid answered entirely from the cache store.  A
    single warm grid lands in milliseconds — far too noisy for the
    ``--compare`` 20% gate — so the rate is measured over repeated
    full reloads until the cumulative sample is >= 0.5 s of wall;
  * ``warm_speedup_x`` — cold wall over best warm wall, the >=20x
    acceptance target;
  * ``episode_marginal_speedup_x`` — scenario what-if ensembles
    (``--episodes``) run prefix-shared through the fork plan vs cold:
    the marginal (non-base) cells must beat cold replay, since each
    forks at its onset instead of re-simulating the shared prefix.

Quick mode shrinks the grids (tier-1 pytest smoke) and asserts the
bit-identity contracts instead of the throughput gates: cache hits
equal live ``CellStats`` byte for byte, and fork-grouped episode grids
equal ``--no-fork`` grids cell for cell.
"""
import json
import tempfile
import time

from benchmarks import common
from benchmarks.common import benchmark

# acceptance (ISSUE 10): the fully-warm repeat of the acceptance grid
# answers >=20x faster than the cold run
ACCEPT_WARM_SPEEDUP = 20.0

# per-cell wall floor (s) when summing marginal walls: forked suffix
# cells round to ~0 and would divide out to infinity
_WALL_FLOOR_S = 0.005

# keep re-running the warm repeat until the cumulative timed sample is
# this big (see module docstring); the rep cap is a runaway backstop,
# the sample-time loop is the real bound
_WARM_SAMPLE_S = 0.5
_WARM_MIN_REPS = 3
_WARM_MAX_REPS = 10_000


def _run_grid(gpus, n_seeds, days, *, procs, min_hours, episodes=(),
              fork=True, cache_dir=None):
    """One ensemble grid run; returns (streamed stats, wall, cache)."""
    from repro.ensemble.cellcache import CellCache
    from repro.ensemble.run import run_ensemble_grid

    stats = []
    # a fresh CellCache per run re-reads the jsonl store, so warm
    # timings include the load a fresh process would pay
    cache = CellCache(cache_dir) if cache_dir else None
    t0 = time.time()
    run_ensemble_grid(gpus, range(n_seeds), horizon_days=days,
                      min_hours=min_hours, procs=procs,
                      episodes=episodes, fork=fork, cache=cache,
                      on_result=lambda i, s, d, t, c: stats.append(s))
    return stats, time.time() - t0, cache


def _coord(d):
    return (d["n_gpus"], d["seed"], d["episode"])


def _dumps(dicts):
    # compare as json text: NaN metrics (cells with no qualifying runs)
    # are real values, and nan != nan under dict equality
    return json.dumps(sorted(dicts, key=_coord))


def _strip(s):
    """to_json minus wall clock and fork provenance (the two fields the
    bit-identity contract exempts)."""
    return {k: v for k, v in s.to_json().items()
            if k not in ("wall_s", "fork")}


def _marginal_wall(stats):
    """Summed wall of the what-if (non-base) cells, floored per cell."""
    return sum(max(s.wall_s, _WALL_FLOOR_S) for s in stats if s.episode)


@benchmark("cache_bench")
def run(rep):
    from repro.ensemble.runner import default_procs

    if common.QUICK:
        gpus, seeds, days, min_hours, procs = [256, 512], 2, 2.0, 4.0, 0
        ep_gpus, ep_seeds, ep_days = [256], 2, 2.0
        episodes = ("rf:2@1",)
    else:
        gpus, seeds, days, min_hours = [1024, 4096, 16384], 16, 8.0, 12.0
        procs = default_procs()
        ep_gpus, ep_seeds, ep_days = [4096], 2, 8.0
        episodes = ("rf:2@6", "outage:64@6")
    rep.label("grid", f"{seeds}seed_x_{len(gpus)}scale_{days:g}d")
    rep.label("procs", procs)

    # -- cold grid, then fully-warm repeats off the same store ----------
    with tempfile.TemporaryDirectory() as td:
        cold, cold_wall, c_cold = _run_grid(
            gpus, seeds, days, procs=procs, min_hours=min_hours,
            cache_dir=td)
        walls, warm_total, reps = [], 0.0, 0
        while (warm_total < _WARM_SAMPLE_S or reps < _WARM_MIN_REPS) \
                and reps < _WARM_MAX_REPS:
            warm, wall, c_warm = _run_grid(
                gpus, seeds, days, procs=procs, min_hours=min_hours,
                cache_dir=td)
            walls.append(wall)
            warm_total += wall
            reps += 1
    warm_wall = min(walls)
    n = len(cold)
    speedup = cold_wall / max(warm_wall, 1e-9)
    rep.add("grid_cells", n)
    rep.add("cold_wall_s", round(cold_wall, 2), f"{max(procs, 1)} procs")
    rep.add("warm_wall_s", round(warm_wall, 4),
            f"best of {reps} full-warm repeats")
    rep.add("warm_speedup_x", round(speedup, 1))
    rep.add("cold_cells_per_sec", round(n / max(cold_wall, 1e-9), 2))
    rep.add("warm_cells_per_sec",
            round(n * reps / max(warm_total, 1e-9), 1),
            f"{reps} repeats over {warm_total:.2f}s")
    rep.check("cold grid stored every cell",
              c_cold.misses == n and c_cold.hits == 0 and len(c_cold) == n,
              f"{c_cold.misses} misses, {len(c_cold)} held")
    rep.check("warm repeat answered fully from the cache",
              c_warm.hits == n and c_warm.misses == 0,
              f"{c_warm.hits}h/{c_warm.misses}m")
    rep.check("cache hits bit-equal live CellStats",
              _dumps(s.to_json() for s in cold)
              == _dumps(s.to_json() for s in warm), f"{n} cells")
    if not common.QUICK:
        rep.check(f"fully-warm repeat >={ACCEPT_WARM_SPEEDUP:.0f}x faster "
                  f"than cold", speedup >= ACCEPT_WARM_SPEEDUP,
                  f"{speedup:.0f}x")

    # -- scenario what-ifs: fork-grouped vs cold marginal cells ---------
    fk, _, _ = _run_grid(ep_gpus, ep_seeds, ep_days, procs=procs,
                         min_hours=min_hours, episodes=episodes)
    cd, _, _ = _run_grid(ep_gpus, ep_seeds, ep_days, procs=procs,
                         min_hours=min_hours, episodes=episodes,
                         fork=False)
    n_ep = sum(1 for s in fk if s.episode)
    marginal = _marginal_wall(cd) / max(_marginal_wall(fk), 1e-9)
    rep.add("episode_grid_cells", len(fk),
            f"{'+'.join(episodes)} at {ep_gpus[0]} GPUs x {ep_seeds} seeds")
    rep.add("episode_marginal_speedup_x", round(marginal, 2),
            f"cold walls / forked walls on {n_ep} what-if cells")
    if common.QUICK:
        rep.check("fork-grouped episode grid == --no-fork grid",
                  _dumps(_strip(s) for s in fk)
                  == _dumps(_strip(s) for s in cd), f"{len(fk)} cells")
    else:
        rep.check("fork-grouped what-if cells beat cold replay",
                  marginal > 1.0, f"{marginal:.2f}x")
