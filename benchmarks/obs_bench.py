"""Live-telemetry (repro.obs) overhead benchmark.

The MetricsRegistry contract is zero-overhead-when-off and cheap-when-on:
this benchmark measures an obs-instrumented ``ClusterSim`` run against a
bare one at the paper's 2000-node scale (quick mode: 200 nodes / 4
days, the tier-1 CI grid) and checks instrumentation overhead stays
under 5%.

Measurement (same methodology as trace_bench): overhead is summed from
its directly-timed components — per-hook cost (microbenchmarked per
call, times the engine's event counts: job ends, sched passes, faults),
the engine-side ``perf_counter`` pair that times each sched pass only
when an obs is attached, the per-snapshot poll cost, and finalize.  On
a shared CI box, differencing two sub-second end-to-end walls swings
±15% run-to-run; timing the small components directly is stable at the
percent level.  The raw instrumented-vs-bare sim delta is still
reported (informational) alongside the component sum.

  PYTHONPATH=src python -m benchmarks.run --only obs_bench [--quick]
"""
import gc
import time

from benchmarks import common
from benchmarks.common import benchmark

MAX_OVERHEAD_FRAC = 0.05
SIM_REPS = 6       # interleaved bare/instrumented sim pairs
PART_REPS = 5      # snapshot / finalize timing repetitions


def _spec(quick: bool):
    from repro.cluster.workload import ClusterSpec

    if quick:
        # the tier-1 CI grid: busy enough that hook costs dominate
        # timing noise, small enough to stay in the pytest budget
        return ClusterSpec("RSC-1", n_nodes=200, jobs_per_day=800.0,
                           target_utilization=0.83, r_f=6.5e-3), 4.0
    # the acceptance scale: RSC-1-sized cluster, saturating workload
    return ClusterSpec("RSC-1", n_nodes=2000, jobs_per_day=8000.0,
                       target_utilization=0.83, r_f=6.5e-3), 4.0


def _run_sim(spec, days, instrumented: bool):
    from repro.cluster.scheduler import ClusterSim
    from repro.obs import MetricsRegistry

    obs = MetricsRegistry() if instrumented else None
    kw = {"horizon_days": days, "seed": 0}
    if obs is not None:
        kw["obs"] = obs
    t0 = time.perf_counter()
    sim = ClusterSim(spec, **kw)
    sim.run()
    return time.perf_counter() - t0, sim, obs


def _timed(fn, reps: int):
    best = float("inf")
    out = None
    for _ in range(reps):
        t0 = time.perf_counter()
        r = fn()
        w = time.perf_counter() - t0
        if w < best:
            best, out = w, r
    return best, out


def _hook_costs_s() -> tuple:
    """Marginal per-event cost of the two hot obs hooks plus the
    engine-side ``perf_counter`` pair.  The pass hook has two paths
    (engine-sampled wall timing vs not), so its cost is the
    stride-weighted average of both, and the timer pair amortizes over
    the stride too."""
    from repro.cluster.scheduler import OBS_PASS_SAMPLE, JobState
    from repro.obs import MetricsRegistry

    n = 20000
    best_job = best_timed = best_untimed = best_timer = float("inf")
    state = JobState.COMPLETED
    for _ in range(3):
        reg = MetricsRegistry()
        # park both boundaries so the microbench never snapshots
        reg._next_snap = reg._next_edge = float("inf")
        hook = reg.on_job_end
        t0 = time.perf_counter()
        for i in range(n):
            hook(30.0 * i, state, 16, 10.0 * i, False)
        best_job = min(best_job, time.perf_counter() - t0)
        hook = reg.on_sched_pass
        t0 = time.perf_counter()
        for i in range(n):
            hook(30.0 * i, 5, 1, 0, False, 2e-5)
        best_timed = min(best_timed, time.perf_counter() - t0)
        t0 = time.perf_counter()
        for i in range(n):
            hook(30.0 * i, 5, 1, 0, False, -1.0)
        best_untimed = min(best_untimed, time.perf_counter() - t0)
        pc = time.perf_counter
        t0 = pc()
        for i in range(n):
            w0 = pc()
            _ = pc() - w0
        best_timer = min(best_timer, pc() - t0)
    stride = OBS_PASS_SAMPLE
    c_pass = (best_timed + (stride - 1) * best_untimed) / stride / n
    c_timer = best_timer / stride / n
    return best_job / n, c_pass, c_timer


@benchmark("obs_bench")
def run(rep):
    spec, days = _spec(common.QUICK)
    label = f"{spec.n_nodes}n_{days:g}d"

    _run_sim(spec, days, False)   # warmup: first run pays import costs
    bare = instrumented = float("inf")
    sim = reg = None
    gc.disable()
    try:
        for i in range(SIM_REPS):
            order = (False, True) if i % 2 == 0 else (True, False)
            for inst in order:
                w, s, r = _run_sim(spec, days, inst)
                if inst and w < instrumented:
                    instrumented, sim, reg = w, s, r
                elif not inst:
                    bare = min(bare, w)
            gc.collect()

        c_job, c_pass, c_timer = _hook_costs_s()
        # per-snapshot poll cost on the *final* (fullest) sim state
        n_live_snaps = len(reg.snapshots)
        t_final = max(sim._now, sim.horizon_s)

        def snap_once():
            reg.snapshots.clear()
            return reg._snapshot(t_final)

        c_snap, _ = _timed(snap_once, PART_REPS)
        fin_s, _ = _timed(lambda: reg.finalize(sim), PART_REPS)
    finally:
        gc.enable()

    n_jobs = reg.jobs_total
    n_passes = reg.sched_passes_total
    n_faults = reg.faults_total
    # faults are rare; their hook is conservatively costed like a job's
    hook_s = (n_jobs * c_job + n_passes * (c_pass + c_timer)
              + n_faults * c_job)
    snap_s = n_live_snaps * c_snap
    overhead = (hook_s + snap_s + fin_s) / bare

    rep.add(f"{label}.bare_run_s", round(bare, 3))
    rep.add(f"{label}.instrumented_minus_bare_s",
            round(instrumented - bare, 4),
            "raw end-to-end delta (noisy on shared CPUs)")
    rep.add(f"{label}.job_hook_ns", round(c_job * 1e9),
            f"x {n_jobs} job-attempt ends")
    rep.add(f"{label}.pass_hook_ns", round((c_pass + c_timer) * 1e9),
            f"x {n_passes} sched passes (stride-averaged; incl. the "
            f"amortized engine-side timer pair)")
    rep.add(f"{label}.snapshot_us", round(c_snap * 1e6, 1),
            f"x {n_live_snaps} snapshots (O(cluster) polls live here)")
    rep.add(f"{label}.hook_cost_s", round(hook_s, 5))
    rep.add(f"{label}.finalize_s", round(fin_s, 5))
    rep.add(f"{label}.obs_overhead", f"{overhead:+.1%}",
            "(hooks + snapshots + finalize) / bare run")
    rep.add(f"{label}.job_attempts", n_jobs)
    rep.add(f"{label}.sched_passes", n_passes)
    rep.add(f"{label}.faults", n_faults)
    rep.add(f"{label}.snapshots", n_live_snaps)
    rep.check(f"obs overhead < {MAX_OVERHEAD_FRAC:.0%} "
              f"(hooks + snapshots + finalize vs bare run)",
              overhead < MAX_OVERHEAD_FRAC, f"{overhead:+.1%}")
    rep.check("registry job count matches the engine's record count",
              n_jobs == sim.n_records, f"{n_jobs} vs {sim.n_records}")
    rep.check("snapshot cadence covered the horizon",
              n_live_snaps >= int(days * 86400.0
                                  / reg.snapshot_interval_s),
              f"{n_live_snaps} snapshots over {days:g} days at "
              f"{reg.snapshot_interval_s / 3600.0:g}h intervals")
