"""Mitigation-lab scale sweep (paper §IV: gauging software mitigations).

Runs the policy x scale grid from ``repro.mitigations.sweep`` — baseline,
lemon eviction, and Daly-Young-optimal checkpoint cadence at 512/2048/8192
GPUs, >=2 seeds each — and checks the acceptance properties:

  * the grid completes in < 5 min on one CPU;
  * the simulated baseline ETTR at each scale lands inside the analytical
    ``ettr_model`` band (model fed the realized interruption rates and
    queue waits, Fig. 9-style; measured is the conservative underestimate);
  * rate-tuned checkpoint cadence shows an ETTR uplift over the hourly
    baseline, and lemon eviction does not hurt.

Quick mode (`benchmarks.run --quick`): a 2-policy x 2-scale x 2-seed smoke
grid at 256/512 GPUs, exercised from tier-1 pytest.
"""
import math

from benchmarks import common
from benchmarks.common import benchmark

# re-calibrated post chain-leak fix on seeds 0-1 at 512/2048/8192 GPUs:
# measured - model lands in [-0.027, -0.009]; the regression band leaves
# generous statistical margin
MODEL_BAND_LO = -0.10
MODEL_BAND_HI = +0.05

# fault-model v2 scenario packs (baseline policy @ 2048 GPUs, seeds 0-1;
# sweep cells are bit-deterministic per seed).  Calibrated diffs:
# independent -0.009, rack-correlated -0.009, slow-detection -0.026 (the
# model never sees the detection lag, so measured falls further below it)
SCENARIO_BANDS = {
    "rack-correlated": (-0.10, +0.05),
    "slow-detection": (-0.12, +0.03),
}
SCENARIO_GPUS = 2048


def _report_cells(rep, res):
    for row in res.aggregate():
        tag = f"{row['policy']}@{row['n_gpus']}gpu"
        rep.add(f"{tag}.ettr", round(row["ettr_sim"], 3),
                f"model {row['ettr_model']:.3f}, "
                f"{row['n_seeds']} seeds")
        if "d_ettr" in row:
            rep.add(f"{tag}.ettr_uplift", round(row["d_ettr"], 3),
                    "vs baseline at same scale/seeds")


@benchmark("fig13_mitigations")
def run(rep):
    from repro.mitigations.sweep import sweep

    if common.QUICK:
        res = sweep(policies=["baseline", "lemon_eviction"],
                    gpus_list=[256, 512], seeds=(0, 1), horizon_days=3.0,
                    min_hours=2.0, procs=0)
        _report_cells(rep, res)
        rep.add("grid.wall_s", round(res.wall_s, 2))
        rep.check("quick smoke grid completes fast", res.wall_s < 60.0,
                  f"{res.wall_s:.1f}s")
        rep.check("every quick cell measured ETTR",
                  all(not math.isnan(c.ettr_sim) for c in res.cells),
                  str([c.n_runs_measured for c in res.cells]))
        # scenario-pack smoke (tier-1): the v2 packs thread through the
        # sweep harness end-to-end at toy scale
        res_s = sweep(policies=["baseline"], gpus_list=[256], seeds=(0,),
                      horizon_days=3.0, min_hours=2.0, procs=0,
                      scenario="slow-detection")
        rep.check("scenario pack threads through the sweep harness",
                  len(res_s.cells) == 1 and res_s.cells[0].n_faults > 0,
                  f"{res_s.cells[0].n_faults} faults")
        return

    policies = ["baseline", "lemon_eviction", "checkpoint_optimal"]
    res = sweep(policies=policies, gpus_list=[512, 2048, 8192],
                seeds=(0, 1), horizon_days=8.0, procs=4)
    _report_cells(rep, res)
    rep.add("grid.cells", len(res.cells))
    rep.add("grid.wall_s", round(res.wall_s, 2))
    rep.check("3-policy x 3-scale x 2-seed grid under 5 min",
              res.wall_s < 300.0, f"{res.wall_s:.1f}s")

    rows = {(r["policy"], r["n_gpus"]): r for r in res.aggregate()}
    for gpus in (512, 2048, 8192):
        base = rows[("baseline", gpus)]
        diff = base["ettr_sim"] - base["ettr_model"]
        rep.check(f"baseline ETTR within analytical band @ {gpus} GPUs",
                  MODEL_BAND_LO <= diff <= MODEL_BAND_HI,
                  f"measured {base['ettr_sim']:.3f} vs model "
                  f"{base['ettr_model']:.3f} (diff {diff:+.3f})")
    uplift = [rows[("checkpoint_optimal", g)]["d_ettr"]
              for g in (512, 2048, 8192)]
    rep.check("rate-tuned checkpoint cadence lifts ETTR at every scale",
              all(u > 0 for u in uplift),
              ", ".join(f"{u:+.3f}" for u in uplift))
    lemon = [rows[("lemon_eviction", g)]["d_ettr"] for g in (512, 2048, 8192)]
    rep.check("lemon eviction does not hurt ETTR (>= -0.02 at every scale)",
              all(u >= -0.02 for u in lemon),
              ", ".join(f"{u:+.3f}" for u in lemon))
    evicted = sum(c.n_evicted for c in res.cells
                  if c.policy == "lemon_eviction")
    rep.check("lemon eviction actually evicts", evicted > 0,
              f"{evicted} evictions across cells")

    # fault-model v2 scenario packs: baseline + tuned cadence per pack,
    # measured-vs-model diff gated against the per-scenario bands above
    indep = rows[("baseline", SCENARIO_GPUS)]
    scen_stats = {}
    for scen in sorted(SCENARIO_BANDS):
        res_s = sweep(policies=["baseline", "checkpoint_optimal"],
                      gpus_list=[SCENARIO_GPUS], seeds=(0, 1),
                      horizon_days=8.0, procs=4, scenario=scen)
        rows_s = {(r["policy"], r["n_gpus"]): r for r in res_s.aggregate()}
        base_s = rows_s[("baseline", SCENARIO_GPUS)]
        diff_s = base_s["ettr_sim"] - base_s["ettr_model"]
        scen_stats[scen] = (diff_s, base_s["goodput"])
        rep.add(f"{scen}.baseline.ettr", round(base_s["ettr_sim"], 3),
                f"model {base_s['ettr_model']:.3f}, diff {diff_s:+.3f}")
        lo, hi = SCENARIO_BANDS[scen]
        rep.check(f"{scen}: baseline ETTR within its calibrated band "
                  f"@ {SCENARIO_GPUS} GPUs",
                  lo <= diff_s <= hi,
                  f"diff {diff_s:+.3f} vs [{lo:+.2f}, {hi:+.2f}]")
        up_s = rows_s[("checkpoint_optimal", SCENARIO_GPUS)]["d_ettr"]
        rep.check(f"{scen}: rate-tuned cadence still lifts ETTR",
                  up_s > 0, f"{up_s:+.3f}")
    indep_diff = indep["ettr_sim"] - indep["ettr_model"]
    rep.check("slow-detection widens the measured-below-model gap vs "
              "independent (same seeds — the model cannot see the "
              "detection lag)",
              scen_stats["slow-detection"][0] < indep_diff,
              f"{scen_stats['slow-detection'][0]:+.3f} vs "
              f"{indep_diff:+.3f}")
    rep.check("rack-correlated blasts do not improve goodput",
              scen_stats["rack-correlated"][1]
              <= indep["goodput"] + 0.005,
              f"{scen_stats['rack-correlated'][1]:.4f} vs independent "
              f"{indep['goodput']:.4f}")
