"""Mitigation-lab scale sweep (paper §IV: gauging software mitigations).

Runs the policy x scale grid from ``repro.mitigations.sweep`` — baseline,
lemon eviction, and Daly-Young-optimal checkpoint cadence at 512/2048/8192
GPUs, >=2 seeds each — and checks the acceptance properties:

  * the grid completes in < 5 min on one CPU;
  * the simulated baseline ETTR at each scale lands inside the analytical
    ``ettr_model`` band (model fed the realized interruption rates and
    queue waits, Fig. 9-style; measured is the conservative underestimate);
  * rate-tuned checkpoint cadence shows an ETTR uplift over the hourly
    baseline, and lemon eviction does not hurt.

Quick mode (`benchmarks.run --quick`): a 2-policy x 2-scale x 2-seed smoke
grid at 256/512 GPUs, exercised from tier-1 pytest.
"""
import math

from benchmarks import common
from benchmarks.common import benchmark

# calibrated on seeds 0-4 at 512/2048/8192 GPUs: measured - model lands in
# [-0.027, -0.009]; the regression band leaves generous statistical margin
MODEL_BAND_LO = -0.10
MODEL_BAND_HI = +0.05


def _report_cells(rep, res):
    for row in res.aggregate():
        tag = f"{row['policy']}@{row['n_gpus']}gpu"
        rep.add(f"{tag}.ettr", round(row["ettr_sim"], 3),
                f"model {row['ettr_model']:.3f}, "
                f"{row['n_seeds']} seeds")
        if "d_ettr" in row:
            rep.add(f"{tag}.ettr_uplift", round(row["d_ettr"], 3),
                    "vs baseline at same scale/seeds")


@benchmark("fig13_mitigations")
def run(rep):
    from repro.mitigations.sweep import sweep

    if common.QUICK:
        res = sweep(policies=["baseline", "lemon_eviction"],
                    gpus_list=[256, 512], seeds=(0, 1), horizon_days=3.0,
                    min_hours=2.0, procs=0)
        _report_cells(rep, res)
        rep.add("grid.wall_s", round(res.wall_s, 2))
        rep.check("quick smoke grid completes fast", res.wall_s < 60.0,
                  f"{res.wall_s:.1f}s")
        rep.check("every quick cell measured ETTR",
                  all(not math.isnan(c.ettr_sim) for c in res.cells),
                  str([c.n_runs_measured for c in res.cells]))
        return

    policies = ["baseline", "lemon_eviction", "checkpoint_optimal"]
    res = sweep(policies=policies, gpus_list=[512, 2048, 8192],
                seeds=(0, 1), horizon_days=8.0, procs=4)
    _report_cells(rep, res)
    rep.add("grid.cells", len(res.cells))
    rep.add("grid.wall_s", round(res.wall_s, 2))
    rep.check("3-policy x 3-scale x 2-seed grid under 5 min",
              res.wall_s < 300.0, f"{res.wall_s:.1f}s")

    rows = {(r["policy"], r["n_gpus"]): r for r in res.aggregate()}
    for gpus in (512, 2048, 8192):
        base = rows[("baseline", gpus)]
        diff = base["ettr_sim"] - base["ettr_model"]
        rep.check(f"baseline ETTR within analytical band @ {gpus} GPUs",
                  MODEL_BAND_LO <= diff <= MODEL_BAND_HI,
                  f"measured {base['ettr_sim']:.3f} vs model "
                  f"{base['ettr_model']:.3f} (diff {diff:+.3f})")
    uplift = [rows[("checkpoint_optimal", g)]["d_ettr"]
              for g in (512, 2048, 8192)]
    rep.check("rate-tuned checkpoint cadence lifts ETTR at every scale",
              all(u > 0 for u in uplift),
              ", ".join(f"{u:+.3f}" for u in uplift))
    lemon = [rows[("lemon_eviction", g)]["d_ettr"] for g in (512, 2048, 8192)]
    rep.check("lemon eviction does not hurt ETTR (>= -0.02 at every scale)",
              all(u >= -0.02 for u in lemon),
              ", ".join(f"{u:+.3f}" for u in lemon))
    evicted = sum(c.n_evicted for c in res.cells
                  if c.policy == "lemon_eviction")
    rep.check("lemon eviction actually evicts", evicted > 0,
              f"{evicted} evictions across cells")
