"""Trace-recording overhead benchmark.

The TraceRecorder contract is zero-overhead-when-off and cheap-when-on:
this benchmark measures the full "record trace -> finalize -> analyze"
path against a bare "run -> analyze counters" baseline at 500-node
scale (quick mode: 200 nodes / 4 days, the tier-1 CI grid) and checks
recording overhead stays under 5% (tightened from 10% once hot-path v3
made finalize a near-free columnar slice/concat; measured ~1%).  Also
reports trace row counts and on-disk npz/jsonl sizes for the recorded
run.

Measurement: overhead is summed from its directly-timed components —
per-event hook cost (microbenchmarked per call, times the recorded
event count), finalize, and the trace-vs-counter analysis delta.  On a
shared CI box, differencing two ~100 ms end-to-end walls swings ±15%
run-to-run; timing the small components directly is stable at the
percent level.  The raw recorded-vs-bare sim delta is still reported
(informational) alongside the component sum.

  PYTHONPATH=src python -m benchmarks.run --only trace_bench [--quick]
"""
import gc
import os
import tempfile
import time

from benchmarks import common
from benchmarks.common import benchmark

MAX_OVERHEAD_FRAC = 0.05
SIM_REPS = 6       # interleaved bare/recorded sim pairs
PART_REPS = 5      # finalize / analysis timing repetitions


def _spec(quick: bool):
    from repro.cluster.workload import ClusterSpec

    if quick:
        # large enough that the overhead components are not dominated by
        # millisecond timing noise, small enough for the tier-1 CI grid
        return ClusterSpec("RSC-1", n_nodes=200, jobs_per_day=800.0,
                           target_utilization=0.83, r_f=6.5e-3), 4.0
    return ClusterSpec("RSC-1", n_nodes=500, jobs_per_day=2000.0,
                       target_utilization=0.83, r_f=6.5e-3), 5.0


def _analyze(jobs_input):
    from repro.cluster import analysis

    analysis.status_breakdown(jobs_input)
    analysis.hw_impact(jobs_input)
    analysis.preemption_cascades(jobs_input)


def _run_sim(spec, days, recorded: bool):
    from repro.cluster.scheduler import ClusterSim
    from repro.trace import TraceRecorder

    rec = TraceRecorder() if recorded else None
    t0 = time.perf_counter()
    sim = ClusterSim(spec, horizon_days=days, seed=0, recorder=rec)
    sim.run()
    return time.perf_counter() - t0, sim, rec


def _timed(fn, reps: int):
    best = float("inf")
    out = None
    for _ in range(reps):
        t0 = time.perf_counter()
        r = fn()
        w = time.perf_counter() - t0
        if w < best:
            best, out = w, r
    return best, out


def _hook_call_cost_s() -> float:
    """Marginal per-event cost of the hottest recorder hook (bound-method
    call + tuple append, as the scheduler's sched branch pays it)."""
    from repro.trace import TraceRecorder

    n = 20000
    best = float("inf")
    queue = []
    for _ in range(3):
        rec = TraceRecorder()
        hook = rec.on_sched_pass
        t0 = time.perf_counter()
        for i in range(n):
            hook(30.0 * i, len(queue), 1, 0, False)
        best = min(best, time.perf_counter() - t0)
    return best / n


@benchmark("trace_bench")
def run(rep):
    from repro.trace import io as trace_io

    spec, days = _spec(common.QUICK)
    label = f"{spec.n_nodes}n_{days:g}d"

    _run_sim(spec, days, False)   # warmup: first run pays import costs
    bare = recorded = float("inf")
    sim = trace = rec = None
    gc.disable()
    try:
        for i in range(SIM_REPS):
            order = (False, True) if i % 2 == 0 else (True, False)
            for recd in order:
                w, s, r = _run_sim(spec, days, recd)
                if recd and w < recorded:
                    recorded, sim, rec = w, s, r
                elif not recd:
                    bare = min(bare, w)
            gc.collect()

        fin_s, trace = _timed(lambda: rec.finalize(sim), PART_REPS)
        an_counters_s, _ = _timed(lambda: _analyze(sim.records), PART_REPS)
        an_trace_s, _ = _timed(lambda: _analyze(trace), PART_REPS)
        per_call_s = _hook_call_cost_s()
    finally:
        gc.enable()

    n_hook_calls = (trace.n_rows("sched_passes")
                    + trace.n_rows("node_events"))
    hook_s = n_hook_calls * per_call_s
    delta_analyze_s = max(an_trace_s - an_counters_s, 0.0)
    baseline_s = bare + an_counters_s
    overhead = (hook_s + fin_s + delta_analyze_s) / baseline_s

    rep.add(f"{label}.bare_run_s", round(bare, 3))
    rep.add(f"{label}.analyze_counters_s", round(an_counters_s, 4))
    rep.add(f"{label}.hook_cost_s", round(hook_s, 5),
            f"{n_hook_calls} events x {per_call_s*1e9:.0f} ns/hook")
    rep.add(f"{label}.recorded_minus_bare_s", round(recorded - bare, 4),
            "raw end-to-end delta (noisy on shared CPUs)")
    rep.add(f"{label}.finalize_s", round(fin_s, 4))
    rep.add(f"{label}.analyze_trace_s", round(an_trace_s, 4))
    rep.add(f"{label}.recording_overhead", f"{overhead:+.1%}",
            "(hooks + finalize + analysis delta) / no-trace path")
    rep.add(f"{label}.job_attempts", trace.n_rows("jobs"))
    rep.add(f"{label}.sched_passes", trace.n_rows("sched_passes"))
    rep.add(f"{label}.node_events", trace.n_rows("node_events"))
    rep.check(f"recording overhead < {MAX_OVERHEAD_FRAC:.0%} "
              f"(record+finalize+analyze vs no-trace run)",
              overhead < MAX_OVERHEAD_FRAC, f"{overhead:+.1%}")
    rep.check("recorded run produced identical record count",
              trace.n_rows("jobs") == sim.n_records,
              f"{trace.n_rows('jobs')} vs {sim.n_records}")

    with tempfile.TemporaryDirectory() as td:
        t0 = time.perf_counter()
        npz = trace_io.save(trace, os.path.join(td, "t.npz"))
        w_npz = time.perf_counter() - t0
        t0 = time.perf_counter()
        back = trace_io.load(npz)
        r_npz = time.perf_counter() - t0
        rep.add("npz.bytes", trace_io.file_size(npz))
        rep.add("npz.save_s/load_s", f"{w_npz:.3f}/{r_npz:.3f}")
        jsonl = trace_io.save(trace, os.path.join(td, "t.jsonl"))
        rep.add("jsonl.bytes", trace_io.file_size(jsonl))
        rep.check("npz round-trip preserves the jobs table",
                  back.n_rows("jobs") == trace.n_rows("jobs")
                  and back.meta == trace.meta)
