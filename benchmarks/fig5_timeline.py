"""Figure 5: failure-rate evolution with episodes + health-check
introductions ('new health checks expose new failure modes').

Runs its own scaled long-horizon sim (100 days, 150 nodes) with the
RSC-1-like episode schedule compressed into the window; the analysis is
trace-driven (faults table + meta of the recorded trace)."""
import numpy as np

from benchmarks.common import benchmark
from repro.cluster import analysis
from repro.cluster.failures import Episode
from repro.cluster.workload import ClusterSpec
from repro.trace import simulate_trace

DAYS = 100.0
EPISODES = (
    Episode("gpu_driver_firmware", 0, 30, 6.0, "GSP-timeout regression"),
    Episode("filesystem_mount", 45, 72, 4.0, "mounts downing nodes"),
    Episode("ib_link_error", 80, 92, 8.0, "IB spike on a few nodes"),
)
CHECKS_INTRODUCED = {"filesystem_mount": 42.0, "gpu_driver_firmware": 20.0}


@benchmark("fig5_timeline")
def run(rep):
    spec = ClusterSpec("RSC-1", n_nodes=150, jobs_per_day=500,
                       target_utilization=0.8, r_f=6.5e-3)
    _, trace = simulate_trace(spec, horizon_days=DAYS, seed=1,
                              episodes=EPISODES,
                              check_introduced=CHECKS_INTRODUCED)
    days, rates = analysis.failure_rate_timeline(trace)
    total = np.zeros(len(days))
    for s, r in rates.items():
        total += r
        rep.add(f"peak_rate.{s}", round(float(r.max()), 2),
                "/1000 node-days (30d rolling)")
    lo = float(np.percentile(total[20:-20], 10))
    hi = float(total[20:-20].max())
    rep.add("total_rate_p10", round(lo, 2))
    rep.add("total_rate_peak", round(hi, 2))
    rep.check("Obs 6: failure rate is dynamic (peak >= 2x quiet; paper "
              "2.5 -> 17.5)", hi >= 2 * max(lo, 0.3), f"{lo:.1f} -> {hi:.1f}")
    ib = rates.get("ib_link_error")
    if ib is not None:
        before = float(ib[40:72].mean())
        during = float(ib[78:95].max())
        rep.add("ib_spike_multiplier", round(during / max(before, 1e-3), 1))
        rep.check("IB-link episode visible (Fig 5 summer spike)",
                  during > 1.5 * max(before, 0.05))
    mount_faults = [f for f in trace.fault_records()
                    if f.symptom == "filesystem_mount"]
    pre = [f for f in mount_faults
           if f.t / 86400 < CHECKS_INTRODUCED["filesystem_mount"]]
    rep.add("mount_faults.before_check_unattributed",
            f"{sum(not f.detectable_by_check for f in pre)}/{len(pre)}")
    rep.check("new mount check exposes a pre-existing failure mode",
              all(not f.detectable_by_check for f in pre)
              and any(f.detectable_by_check for f in mount_faults))
