"""Simulation-engine throughput benchmark (jobs simulated / second).

Tracks the event-driven scheduler core's perf trajectory: the paper's
headline analyses cover 11 months x {2000, 1000} nodes x ~4M jobs, so the
full-trace replays the figure benchmarks depend on must stay minutes-fast
on one CPU.  Reports wall-time and jobs/sec at 500- and 2000-node scales,
plus a full RSC-1 11-month replay; checks the >=10x speedup over the
pre-rewrite (eager-tick, set-scan) seed scheduler and the >=2x hot-path-v2
speedup over the PR-1 engine at the 2000-node scale.

Quick mode (`benchmarks.run --quick`) runs a 100-node/2-day smoke scale
only — used by the tier-1 test to catch perf-path API regressions.

Profile mode (`benchmarks.run --only sim_bench --profile`) runs one replay
under cProfile and prints the top-20 cumulative hotspots — the tooling
this and future perf PRs use to pick targets.
"""
import time

from benchmarks import common
from benchmarks.common import benchmark

# measured on the seed implementation (eager 30 s ticks, full_free set
# scans, per-job Python-loop workload gen) at 500 nodes / 5 days / 10980
# job attempts on this repo's reference CPU — the >=10x target baseline
SEED_JOBS_PER_SEC_500N_5D = 1766.0

# measured on the PR-1 engine (lazy ticks, bucket index, string event
# kinds, per-pass deferred re-heapification) at 2000 nodes / 5 days on the
# same reference CPU — the hot-path-v2 >=2x target baseline
PR1_JOBS_PER_SEC_2000N_5D = 26065.0


def _run_scale(rep, label, spec, days, seed=0):
    from repro.cluster.scheduler import ClusterSim

    t0 = time.time()
    sim = ClusterSim(spec, horizon_days=days, seed=seed)
    sim.run()
    wall = time.time() - t0
    jobs = len(sim.records)
    jps = jobs / max(wall, 1e-9)
    rep.add(f"{label}.wall_s", round(wall, 2))
    rep.add(f"{label}.job_attempts", jobs)
    rep.add(f"{label}.jobs_per_sec", round(jps))
    return wall, jps


def _profile(rep, spec, days):
    """One replay under cProfile: top-20 cumulative hotspots to stdout."""
    import cProfile
    import io
    import pstats

    from repro.cluster.scheduler import ClusterSim

    sim = ClusterSim(spec, horizon_days=days, seed=0)
    prof = cProfile.Profile()
    prof.enable()
    sim.run()
    prof.disable()
    buf = io.StringIO()
    pstats.Stats(prof, stream=buf).sort_stats("cumulative").print_stats(20)
    print(buf.getvalue())
    rep.add("profiled_job_attempts", len(sim.records))
    rep.add("profiled_scale", f"{spec.n_nodes}n_{days:g}d")
    rep.check("profile mode completed", True, "top-20 cumulative printed")


@benchmark("sim_bench")
def run(rep):
    from repro.cluster.workload import RSC1, RSC2, ClusterSpec

    if common.PROFILE:
        if common.QUICK:
            spec = ClusterSpec("RSC-1", n_nodes=100, jobs_per_day=400.0,
                               target_utilization=0.83, r_f=6.5e-3)
            rep.label("scale", "profile_100n_2d")
            _profile(rep, spec, 2.0)
        else:
            rep.label("scale", "profile_2000n_5d")
            _profile(rep, RSC1, 5.0)
        return

    if common.QUICK:
        rep.label("scale", "100n_2d")
        spec = ClusterSpec("RSC-1", n_nodes=100, jobs_per_day=400.0,
                           target_utilization=0.83, r_f=6.5e-3)
        wall, jps = _run_scale(rep, "quick_100n_2d", spec, 2.0)
        rep.check("quick smoke scale completes fast", wall < 30.0,
                  f"{wall:.2f}s")
        return

    rep.label("scales", ["500n_5d", "2000n_5d", "rsc1_330d", "rsc2_330d"])
    spec500 = ClusterSpec("RSC-1", n_nodes=500, jobs_per_day=2000.0,
                          target_utilization=0.83, r_f=6.5e-3)
    _, jps500 = _run_scale(rep, "500n_5d", spec500, 5.0)
    rep.add("500n_5d.speedup_vs_seed",
            round(jps500 / SEED_JOBS_PER_SEC_500N_5D, 1),
            f"seed engine: {SEED_JOBS_PER_SEC_500N_5D:.0f} jobs/s")
    rep.check("500n/5d >=10x jobs/sec over seed scheduler",
              jps500 >= 10.0 * SEED_JOBS_PER_SEC_500N_5D,
              f"{jps500:.0f} vs {SEED_JOBS_PER_SEC_500N_5D:.0f} jobs/s")

    # paper-scale cluster, short horizon: stresses per-event constants at
    # 2000 nodes / 7.2k jobs/day — the hot-path-v2 headline scale
    _, jps2000 = _run_scale(rep, "2000n_5d", RSC1, 5.0)
    rep.add("2000n_5d.speedup_vs_pr1",
            round(jps2000 / PR1_JOBS_PER_SEC_2000N_5D, 2),
            f"PR-1 engine: {PR1_JOBS_PER_SEC_2000N_5D:.0f} jobs/s")
    rep.check("2000n/5d >=2x jobs/sec over PR-1 engine (hot-path v2)",
              jps2000 >= 2.0 * PR1_JOBS_PER_SEC_2000N_5D,
              f"{jps2000:.0f} vs {PR1_JOBS_PER_SEC_2000N_5D:.0f} jobs/s")

    # the headline scale: full 11-month RSC-1 replay (~2.4M job attempts)
    wall1, jps1 = _run_scale(rep, "rsc1_330d_full", RSC1, 330.0)
    rep.check("full RSC-1 11-month replay under 5 min",
              wall1 < 300.0, f"{wall1:.1f}s")

    # RSC-2 companion replay (1000 nodes, 4.4k jobs/day)
    wall2, _ = _run_scale(rep, "rsc2_330d_full", RSC2, 330.0)
    rep.check("full RSC-2 11-month replay under 5 min",
              wall2 < 300.0, f"{wall2:.1f}s")
