"""Simulation-engine throughput benchmark (jobs simulated / second).

Tracks the event-driven scheduler core's perf trajectory: the paper's
headline analyses cover 11 months x {2000, 1000} nodes x ~4M jobs, so the
full-trace replays the figure benchmarks depend on must stay minutes-fast
on one CPU.  Reports wall-time, jobs/sec, and peak RSS at 500- and
2000-node scales plus full RSC-1/RSC-2 11-month replays; checks the
>=10x speedup over the pre-rewrite seed scheduler, the >=1.5x hot-path-v3
speedup over the committed PR-4 baseline at the 2000-node scale, and the
55 s RSC-1 330-day budget.

Constant-memory section (full mode): two spill-mode replays
(``TraceRecorder(trace_spill_dir=...)``) run in fresh subprocesses — a
30-day and a 330-day RSC-1 horizon — and the peak-RSS ratio must stay
within 1.5x, evidencing that the chunked columnar stores + disk-backed
arrival blocks keep recording RSS flat in the horizon.

Quick mode (`benchmarks.run --quick`) runs a 100-node/2-day smoke scale
(plus an in-process spill-mode smoke) — used by the tier-1 test to catch
perf-path API regressions.

Profile mode (`benchmarks.run --only sim_bench --profile`) runs one replay
under cProfile and prints the top-20 cumulative hotspots — the tooling
this and future perf PRs use to pick targets.
"""
import json
import os
import subprocess
import sys
import tempfile
import time

from benchmarks import common
from benchmarks.common import benchmark, peak_rss_mb

# measured on the seed implementation (eager 30 s ticks, full_free set
# scans, per-job Python-loop workload gen) at 500 nodes / 5 days / 10980
# job attempts on this repo's reference CPU — the >=10x target baseline
SEED_JOBS_PER_SEC_500N_5D = 1766.0

# measured on the PR-1 engine (lazy ticks, bucket index, string event
# kinds, per-pass deferred re-heapification) at 2000 nodes / 5 days on the
# same reference CPU
PR1_JOBS_PER_SEC_2000N_5D = 26065.0

# historical PR-4 (hot-path v2) numbers at 2000 nodes / 5 days and the
# PR-4 full RSC-1 330-day wall — kept informational; the regression gate
# compares against the *committed* BENCH_sim.json baseline instead
# (same semantics as `benchmarks.run --compare`: fail on a >20% drop)
PR4_JOBS_PER_SEC_2000N_5D = 54829.0
PR4_RSC1_330D_WALL_S = 76.4
V3_RSC1_330D_BUDGET_S = 55.0
BASELINE_MAX_DROP = 0.20

# spill-mode constant-memory gate: 330-day recording RSS vs 30-day
SPILL_RSS_RATIO_MAX = 1.5


def committed_baseline_jps(key: str = "2000n_5d.jobs_per_sec"):
    """The committed BENCH_sim.json throughput baseline for ``key``
    (None when the file or row is absent — e.g. a fresh checkout before
    the first baseline regeneration)."""
    path = os.path.join(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))), "BENCH_sim.json")
    try:
        with open(path) as f:
            base = json.load(f)
        rows = base["benchmarks"]["sim_bench"]["rows"]
    except (OSError, KeyError, json.JSONDecodeError):
        return None
    for k, v, _ in rows:
        if k == key:
            try:
                return float(v)
            except (TypeError, ValueError):
                return None
    return None


def _run_scale(rep, label, spec, days, seed=0):
    from repro.cluster.scheduler import ClusterSim

    t0 = time.time()
    sim = ClusterSim(spec, horizon_days=days, seed=seed)
    sim.run()
    wall = time.time() - t0
    jobs = sim.n_records
    jps = jobs / max(wall, 1e-9)
    rep.add(f"{label}.wall_s", round(wall, 2))
    rep.add(f"{label}.job_attempts", jobs)
    rep.add(f"{label}.jobs_per_sec", round(jps))
    return wall, jps


# run in a fresh subprocess so each horizon's peak RSS is its own
# high-water mark.  The child samples /proc/self/statm on a background
# thread instead of ru_maxrss: on Linux ru_maxrss lives in the
# signal_struct and *survives execve*, so a child spawned from this
# (fat, post-replay) benchmark process would just report the parent's
# peak; sandbox kernels may also omit VmHWM from /proc/self/status
_SPILL_SNIPPET = """\
import json, os, sys, tempfile, threading, time
from repro.cluster.scheduler import ClusterSim
from repro.cluster.workload import RSC1
from repro.trace import TraceRecorder
days = float(sys.argv[1])
page = os.sysconf("SC_PAGE_SIZE")
peak = [0]
done = threading.Event()
def _sample():
    while True:
        with open("/proc/self/statm") as f:
            rss = int(f.read().split()[1]) * page
        if rss > peak[0]:
            peak[0] = rss
        if done.is_set():
            return
        time.sleep(0.02)
thr = threading.Thread(target=_sample, daemon=True)
thr.start()
with tempfile.TemporaryDirectory() as td:
    t0 = time.perf_counter()
    rec = TraceRecorder(trace_spill_dir=td)
    sim = ClusterSim(RSC1, horizon_days=days, seed=0, recorder=rec)
    sim.run()
    rec.finalize(sim)
    wall = time.perf_counter() - t0
    done.set()
    thr.join()
    print(json.dumps({"wall_s": wall, "jobs": sim.n_records,
                      "peak_rss_mb": peak[0] / 1048576.0}))
"""


def _spill_replay_subprocess(days: float) -> dict:
    proc = subprocess.run(
        [sys.executable, "-c", _SPILL_SNIPPET, str(days)],
        capture_output=True, text=True, timeout=600,
        env=dict(os.environ))
    if proc.returncode != 0:
        raise RuntimeError(f"spill replay subprocess failed:\n{proc.stderr}")
    return json.loads(proc.stdout.strip().splitlines()[-1])


def _profile(rep, spec, days):
    """One replay under cProfile: top-20 cumulative hotspots to stdout."""
    import cProfile
    import io
    import pstats

    from repro.cluster.scheduler import ClusterSim

    sim = ClusterSim(spec, horizon_days=days, seed=0)
    prof = cProfile.Profile()
    prof.enable()
    sim.run()
    prof.disable()
    buf = io.StringIO()
    pstats.Stats(prof, stream=buf).sort_stats("cumulative").print_stats(20)
    print(buf.getvalue())
    rep.add("profiled_job_attempts", sim.n_records)
    rep.add("profiled_scale", f"{spec.n_nodes}n_{days:g}d")
    rep.check("profile mode completed", True, "top-20 cumulative printed")


@benchmark("sim_bench", native_profile=True)
def run(rep):
    from repro.cluster.workload import RSC1, RSC2, ClusterSpec

    if common.PROFILE:
        if common.QUICK:
            spec = ClusterSpec("RSC-1", n_nodes=100, jobs_per_day=400.0,
                               target_utilization=0.83, r_f=6.5e-3)
            rep.label("scale", "profile_100n_2d")
            _profile(rep, spec, 2.0)
        else:
            rep.label("scale", "profile_2000n_5d")
            _profile(rep, RSC1, 5.0)
        return

    if common.QUICK:
        from repro.cluster.scheduler import ClusterSim
        rep.label("scale", "100n_2d")
        spec = ClusterSpec("RSC-1", n_nodes=100, jobs_per_day=400.0,
                           target_utilization=0.83, r_f=6.5e-3)
        # best-of-3: the quick smoke replay runs in ~50 ms, so a single
        # sample's jobs/sec whipsaws with scheduler jitter and trips the
        # --compare throughput gate; damp it like the 2000n_5d row
        wall, jobs = float("inf"), 0
        for _ in range(3):
            t0 = time.time()
            sim = ClusterSim(spec, horizon_days=2.0, seed=0)
            sim.run()
            wall = min(wall, time.time() - t0)
            jobs = sim.n_records
        rep.add("quick_100n_2d.wall_s", round(wall, 3), "best of 3")
        rep.add("quick_100n_2d.job_attempts", jobs)
        rep.add("quick_100n_2d.jobs_per_sec",
                round(jobs / max(wall, 1e-9)), "best of 3")
        rep.check("quick smoke scale completes fast", wall < 30.0,
                  f"{wall:.2f}s")
        # spill-mode smoke: records to disk parts, reloads, row counts match
        from repro.trace import TraceRecorder
        from repro.trace import io as trace_io

        with tempfile.TemporaryDirectory() as td:
            rec = TraceRecorder(trace_spill_dir=td)
            sim = ClusterSim(spec, horizon_days=2.0, seed=0, recorder=rec)
            sim.run()
            trace = rec.finalize(sim)
            back = trace_io.load(td)
            rep.add("quick_spill.job_attempts", trace.n_rows("jobs"))
            rep.check("spill-mode trace round-trips through its parts",
                      back.n_rows("jobs") == sim.n_records
                      and back.meta == trace.meta)
        rep.add("peak_rss_mb", round(peak_rss_mb(), 1))
        return

    rep.label("scales", ["500n_5d", "2000n_5d", "rsc1_330d", "rsc2_330d",
                         "spill_rsc1_30d_vs_330d"])
    spec500 = ClusterSpec("RSC-1", n_nodes=500, jobs_per_day=2000.0,
                          target_utilization=0.83, r_f=6.5e-3)
    _, jps500 = _run_scale(rep, "500n_5d", spec500, 5.0)
    rep.add("500n_5d.speedup_vs_seed",
            round(jps500 / SEED_JOBS_PER_SEC_500N_5D, 1),
            f"seed engine: {SEED_JOBS_PER_SEC_500N_5D:.0f} jobs/s")
    rep.check("500n/5d >=10x jobs/sec over seed scheduler",
              jps500 >= 10.0 * SEED_JOBS_PER_SEC_500N_5D,
              f"{jps500:.0f} vs {SEED_JOBS_PER_SEC_500N_5D:.0f} jobs/s")

    # paper-scale cluster, short horizon: stresses per-event constants at
    # 2000 nodes / 7.2k jobs/day — the hot-path headline scale.
    # best-of-3: the v3 target is a 1.5x ratio against a committed
    # baseline number, so damp scheduler jitter on shared boxes
    best_wall, best_jps = min(
        (_run_scale(rep, f"2000n_5d.t{i}", RSC1, 5.0) for i in range(3)),
        key=lambda wj: wj[0])
    # canonical keys (best-of-3) keep the --compare gate and the perf
    # trajectory continuous with the PR-4 baseline's row names
    rep.add("2000n_5d.wall_s", round(best_wall, 2), "best of 3")
    rep.add("2000n_5d.jobs_per_sec", round(best_jps), "best of 3")
    rep.add("2000n_5d.speedup_vs_pr1",
            round(best_jps / PR1_JOBS_PER_SEC_2000N_5D, 2),
            f"PR-1 engine: {PR1_JOBS_PER_SEC_2000N_5D:.0f} jobs/s")
    rep.add("2000n_5d.speedup_vs_pr4",
            round(best_jps / PR4_JOBS_PER_SEC_2000N_5D, 2),
            f"PR-4 historical: {PR4_JOBS_PER_SEC_2000N_5D:.0f} jobs/s")
    base_jps = committed_baseline_jps()
    if base_jps:
        rep.check(f"2000n/5d within {BASELINE_MAX_DROP:.0%} of committed "
                  "BENCH_sim.json baseline",
                  best_jps >= (1.0 - BASELINE_MAX_DROP) * base_jps,
                  f"{best_jps:.0f} vs baseline {base_jps:.0f} jobs/s "
                  f"(floor {(1.0 - BASELINE_MAX_DROP) * base_jps:.0f})")
    else:
        rep.add("2000n_5d.baseline", "absent",
                "no committed BENCH_sim.json row; regression gate skipped")

    # the headline scale: full 11-month RSC-1 replay (~2.6M job attempts)
    wall1, jps1 = _run_scale(rep, "rsc1_330d_full", RSC1, 330.0)
    rep.add("rsc1_330d_full.speedup_vs_pr4",
            round(PR4_RSC1_330D_WALL_S / wall1, 2),
            f"PR-4 committed wall: {PR4_RSC1_330D_WALL_S:.0f}s")
    rep.check("full RSC-1 11-month replay under 5 min",
              wall1 < 300.0, f"{wall1:.1f}s")
    rep.check(f"full RSC-1 11-month replay <= {V3_RSC1_330D_BUDGET_S:.0f}s "
              "(hot-path v3 budget)",
              wall1 <= V3_RSC1_330D_BUDGET_S, f"{wall1:.1f}s")

    # RSC-2 companion replay (1000 nodes, 4.4k jobs/day)
    wall2, _ = _run_scale(rep, "rsc2_330d_full", RSC2, 330.0)
    rep.check("full RSC-2 11-month replay under 5 min",
              wall2 < 300.0, f"{wall2:.1f}s")
    rep.add("peak_rss_mb", round(peak_rss_mb(), 1),
            "bare replays, this process high-water")

    # constant-memory recording: spill-mode 30d vs 330d RSC-1 replays in
    # fresh subprocesses; flat RSS is the hot-path-v3 spill claim
    short = _spill_replay_subprocess(30.0)
    long_ = _spill_replay_subprocess(330.0)
    ratio = long_["peak_rss_mb"] / max(short["peak_rss_mb"], 1e-9)
    rep.add("spill_30d.peak_rss_mb", round(short["peak_rss_mb"], 1),
            f"{short['jobs']} jobs, {short['wall_s']:.1f}s")
    rep.add("spill_330d.peak_rss_mb", round(long_["peak_rss_mb"], 1),
            f"{long_['jobs']} jobs, {long_['wall_s']:.1f}s")
    rep.add("spill_330d_vs_30d.rss_ratio", round(ratio, 2))
    rep.check(f"spill-mode 330d recording RSS flat vs 30d "
              f"(<= {SPILL_RSS_RATIO_MAX}x)",
              ratio <= SPILL_RSS_RATIO_MAX, f"{ratio:.2f}x")
