"""Figure 3: scheduler job-status breakdown by jobs and GPU runtime.

Trace-driven: analyzes the shared sim's recorded trace (record trace ->
analyze trace), not in-engine counters."""
from benchmarks.common import benchmark, get_trace
from repro.cluster import analysis


@benchmark("fig3_job_status")
def run(rep):
    trace = get_trace("RSC-1")
    sb = analysis.status_breakdown(trace)
    for state, frac in sorted(sb["jobs"].items(), key=lambda kv: -kv[1]):
        rep.add(f"jobs.{state}", round(frac, 4))
    for state, frac in sorted(sb["gpu_time"].items(), key=lambda kv: -kv[1]):
        rep.add(f"gpu_time.{state}", round(frac, 4))
    imp = analysis.hw_impact(trace)
    rep.add("hw_attributed.job_fraction", round(imp["hw_job_fraction"], 5),
            "paper: ~0.2%")
    rep.add("hw_attributed.runtime_fraction",
            round(imp["hw_runtime_fraction"], 4), "paper: 18.7%")
    rep.check("~60% of jobs complete (paper: 60%)",
              0.45 <= sb["jobs"].get("COMPLETED", 0) <= 0.75)
    rep.check("~24% user-FAILED (paper: 24%)",
              0.12 <= sb["jobs"].get("FAILED", 0) <= 0.35)
    rep.check("NODE_FAIL rare by job count (paper: 0.1%)",
              sb["jobs"].get("NODE_FAIL", 0) <= 0.01)
    rep.check("Obs 4: HW failures <1% of jobs but >8% of GPU runtime",
              imp["hw_job_fraction"] < 0.01
              and imp["hw_runtime_fraction"] > 0.08,
              f"runtime {imp['hw_runtime_fraction']:.1%}")
