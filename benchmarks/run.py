"""Benchmark suite entry point: one benchmark per paper figure/table.

  PYTHONPATH=src python -m benchmarks.run [--only fig7_mttf] [--json out.json]
"""
from __future__ import annotations

import argparse
import json
import sys
import time
import traceback

# importing registers each benchmark
from benchmarks import (fig3_job_status, fig4_attribution, fig5_timeline,  # noqa: F401
                        fig6_job_mix, fig7_mttf, fig8_goodput_loss,
                        fig9_ettr, fig10_contours, fig12_adaptive_routing,
                        fig13_mitigations, kernel_bench, roofline_table,
                        runtime_ettr, sim_bench, table2_lemon, trace_bench)
from benchmarks import common
from benchmarks.common import all_benchmarks


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None)
    ap.add_argument("--json", default=None)
    ap.add_argument("--quick", action="store_true",
                    help="small-scale defaults (CI smoke mode)")
    args = ap.parse_args()
    common.QUICK = args.quick
    if args.only and args.only not in all_benchmarks():
        names = "\n  ".join(sorted(all_benchmarks()))
        ap.error(f"unknown benchmark {args.only!r}; registered benchmarks:"
                 f"\n  {names}")

    t0 = time.time()
    results = {}
    n_warn = 0
    failures = []
    for name, fn in all_benchmarks().items():
        if args.only and args.only != name:
            continue
        try:
            rep = fn()
            rep.print()
            results[name] = {
                "rows": [[k, str(v), n] for k, v, n in rep.rows],
                "checks": [[d, ok, det] for d, ok, det in rep.checks],
                "wall_s": rep.wall_s,
            }
            n_warn += sum(1 for _, ok, _ in rep.checks if not ok)
        except Exception as e:  # noqa: BLE001
            failures.append(name)
            print(f"\n=== {name} === ERROR: {type(e).__name__}: {e}")
            traceback.print_exc()
    total_checks = sum(len(r["checks"]) for r in results.values())
    passed = total_checks - n_warn
    print(f"\n{'='*70}")
    print(f"benchmarks: {len(results)} ran, {len(failures)} errored "
          f"({failures if failures else ''})")
    print(f"paper-claim checks: {passed}/{total_checks} passed, "
          f"{n_warn} warnings; total {time.time()-t0:.0f}s")
    if args.json:
        with open(args.json, "w") as f:
            json.dump(results, f, indent=1)
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
