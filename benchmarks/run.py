"""Benchmark suite entry point: one benchmark per paper figure/table.

  PYTHONPATH=src python -m benchmarks.run [--only fig7_mttf[,sim_bench]]
      [--json out.json] [--quick] [--profile]
      [--compare BENCH_sim.json]

``--json`` writes a machine-readable trajectory point: per-benchmark rows,
checks, wall-clock, and scale labels plus the git SHA and timestamp of the
run (see BENCH_sim.json for the committed sim_bench + ensemble_bench
baseline).  ``--profile`` runs the ``--only`` selection under cProfile
and prints the top cumulative hotspots: natively profile-aware
benchmarks (sim_bench) swap to a representative single workload, the
rest get a generic whole-benchmark cProfile wrap.  ``--profile``
without ``--only`` is an error (it lists the registered benchmarks).

``--compare BASELINE.json`` is the perf-regression gate: after the run it
diffs every numeric metric shared with the baseline file (printing
per-metric deltas) and exits non-zero if any throughput metric — a row
key ending in ``jobs_per_sec`` or ``cells_per_sec`` — dropped by more
than 20%.  Metrics (and whole benchmarks) present in the current run
but absent from the baseline are noted and skipped, never gated —
regenerate the baseline to start gating them.  Unless ``--only``
narrows further, the run is restricted to the benchmarks present in
the baseline.
"""
from __future__ import annotations

import argparse
import json
import platform
import sys
import time
import traceback

# importing registers each benchmark
from benchmarks import (cache_bench, ensemble_bench, fig3_job_status,  # noqa: F401
                        fig4_attribution, fig5_timeline, fig6_job_mix,
                        fig7_mttf,
                        fig8_goodput_loss, fig9_ettr, fig10_contours,
                        fig11_scale_projection, fig12_adaptive_routing,
                        fig13_mitigations, fork_bench, kernel_bench,
                        obs_bench, roofline_table, runtime_ettr, sim_bench,
                        stat_bench, table2_lemon, trace_bench)
from benchmarks import common
from benchmarks.common import all_benchmarks


_THROUGHPUT_SUFFIXES = ("jobs_per_sec", "cells_per_sec")
_MAX_THROUGHPUT_DROP = 0.20

_REGEN_HINT = (
    "regenerate it from a clean tree with:\n"
    "  PYTHONPATH=src python -m benchmarks.run "
    "--only sim_bench,ensemble_bench,stat_bench,fork_bench,cache_bench "
    "--json BENCH_sim.json")


def _load_baseline(path: str) -> dict:
    """Read a ``--compare`` baseline, failing fast with a regeneration
    recipe when the file is missing or not a benchmark-run json."""
    try:
        with open(path) as f:
            base = json.load(f)
    except FileNotFoundError:
        sys.exit(f"error: --compare baseline {path!r} does not exist; "
                 f"{_REGEN_HINT}")
    except (OSError, json.JSONDecodeError) as e:
        sys.exit(f"error: --compare baseline {path!r} is unreadable "
                 f"({e}); {_REGEN_HINT}")
    if not isinstance(base, dict) or "benchmarks" not in base:
        sys.exit(f"error: --compare baseline {path!r} has no "
                 f"'benchmarks' section (not a benchmarks.run --json "
                 f"file?); {_REGEN_HINT}")
    return base


def _numeric(v):
    try:
        f = float(v)
    except (TypeError, ValueError):
        return None
    return f


def compare_results(baseline_path: str, results: dict) -> int:
    """Print per-metric deltas vs a ``--json`` baseline file; return the
    number of >20% throughput regressions (jobs/sec, cells/sec)."""
    base = _load_baseline(baseline_path)
    sha = base.get("meta", {}).get("git_sha", "?")
    print(f"\n=== regression diff vs {baseline_path} (baseline git {sha}) "
          f"===")
    regressions = 0
    compared = 0
    new_metrics = 0
    base_benchmarks = base.get("benchmarks", {})
    for name, bres in base_benchmarks.items():
        cur = results.get(name)
        if cur is None:
            print(f"  {name}: not run (skipped in diff)")
            continue
        cur_rows = {k: v for k, v, _ in cur["rows"]}
        base_keys = {key for key, _, _ in bres.get("rows", [])}
        for key, bval, _ in bres.get("rows", []):
            bnum = _numeric(bval)
            cnum = _numeric(cur_rows.get(key))
            if bnum is None or cnum is None or bnum == 0:
                continue
            delta = (cnum - bnum) / abs(bnum)
            flag = ""
            if (key.endswith(_THROUGHPUT_SUFFIXES)
                    and delta < -_MAX_THROUGHPUT_DROP):
                regressions += 1
                flag = f"  << REGRESSION (>{_MAX_THROUGHPUT_DROP:.0%} drop)"
            print(f"  {name}.{key:52s} {bnum:>12.6g} -> {cnum:>12.6g} "
                  f"{delta:+8.1%}{flag}")
            compared += 1
        # metrics the current run has that the baseline predates: noted
        # and skipped, never gated — a new metric needs a regenerated
        # baseline, not a green-by-accident diff
        for key in (k for k, _, _ in cur["rows"] if k not in base_keys):
            if _numeric(cur_rows.get(key)) is None:
                continue
            new_metrics += 1
            print(f"  {name}.{key:52s} (new metric — not in baseline; "
                  f"skipped, regenerate the baseline to gate it)")
    for name in sorted(set(results) - set(base_benchmarks)):
        print(f"  {name}: new benchmark — not in baseline; skipped "
              f"(regenerate the baseline to gate it)")
    print(f"  {compared} shared metrics compared, "
          f"{new_metrics} new metrics skipped, "
          f"{regressions} throughput regressions")
    if not compared:
        print("  (no comparable numeric metrics — quick runs only compare "
              "against quick baselines)")
    return regressions


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated benchmark names")
    ap.add_argument("--json", default=None)
    ap.add_argument("--quick", action="store_true",
                    help="small-scale defaults (CI smoke mode)")
    ap.add_argument("--profile", action="store_true",
                    help="cProfile the --only selection: top-20 "
                         "cumulative hotspots per benchmark (requires "
                         "--only; any registered benchmark works)")
    ap.add_argument("--compare", default=None, metavar="BASELINE_JSON",
                    help="regression-diff mode: print per-metric deltas "
                         "vs a benchmarks.run --json file and exit "
                         "non-zero on a >20%% jobs/sec or cells/sec drop")
    args = ap.parse_args()
    common.QUICK = args.quick
    common.PROFILE = args.profile
    only = set(args.only.split(",")) if args.only else None
    if args.profile and only is None:
        names = "\n  ".join(sorted(all_benchmarks()))
        ap.error("--profile needs --only to pick what to profile; "
                 f"registered benchmarks:\n  {names}")
    if args.compare and only is None:
        # default the run to the baseline's benchmark set (fails fast on
        # a missing/unreadable baseline, before any benchmark runs)
        only = set(_load_baseline(args.compare)["benchmarks"])
    if only:
        unknown = only - set(all_benchmarks())
        if unknown:
            names = "\n  ".join(sorted(all_benchmarks()))
            ap.error(f"unknown benchmark(s) {sorted(unknown)}; registered "
                     f"benchmarks:\n  {names}")

    t0 = time.time()
    results = {}
    n_warn = 0
    failures = []
    for name, fn in all_benchmarks().items():
        if only and name not in only:
            continue
        try:
            if args.profile and not getattr(fn, "native_profile", False):
                rep = common.profile_call(name, fn)
            else:
                rep = fn()
            rep.print()
            results[name] = {
                "rows": [[k, str(v), n] for k, v, n in rep.rows],
                "checks": [[d, ok, det] for d, ok, det in rep.checks],
                "wall_s": rep.wall_s,
                "labels": rep.meta,
            }
            n_warn += sum(1 for _, ok, _ in rep.checks if not ok)
        except Exception as e:  # noqa: BLE001
            failures.append(name)
            print(f"\n=== {name} === ERROR: {type(e).__name__}: {e}")
            traceback.print_exc()
    total_checks = sum(len(r["checks"]) for r in results.values())
    passed = total_checks - n_warn
    wall = time.time() - t0
    print(f"\n{'='*70}")
    print(f"benchmarks: {len(results)} ran, {len(failures)} errored "
          f"({failures if failures else ''})")
    print(f"paper-claim checks: {passed}/{total_checks} passed, "
          f"{n_warn} warnings; total {wall:.0f}s")
    if args.json:
        out = {
            "meta": {
                "git_sha": common.git_sha(),
                "timestamp": time.strftime("%Y-%m-%dT%H:%M:%S"),
                "python": platform.python_version(),
                "machine": platform.machine(),
                "quick": args.quick,
                "wall_s": round(wall, 2),
            },
            "benchmarks": results,
        }
        with open(args.json, "w") as f:
            json.dump(out, f, indent=1)
    if failures:
        sys.exit(1)
    if args.compare:
        if compare_results(args.compare, results):
            sys.exit(2)


if __name__ == "__main__":
    main()
