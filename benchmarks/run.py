"""Benchmark suite entry point: one benchmark per paper figure/table.

  PYTHONPATH=src python -m benchmarks.run [--only fig7_mttf[,sim_bench]]
      [--json out.json] [--quick] [--profile]

``--json`` writes a machine-readable trajectory point: per-benchmark rows,
checks, wall-clock, and scale labels plus the git SHA and timestamp of the
run (see BENCH_sim.json for the committed sim_bench + ensemble_bench
baseline).  ``--profile`` runs profile-aware benchmarks (sim_bench) under
cProfile and prints the top cumulative hotspots instead of timings.
"""
from __future__ import annotations

import argparse
import json
import platform
import sys
import time
import traceback

# importing registers each benchmark
from benchmarks import (ensemble_bench, fig3_job_status, fig4_attribution,  # noqa: F401
                        fig5_timeline, fig6_job_mix, fig7_mttf,
                        fig8_goodput_loss, fig9_ettr, fig10_contours,
                        fig11_scale_projection, fig12_adaptive_routing,
                        fig13_mitigations, kernel_bench, roofline_table,
                        runtime_ettr, sim_bench, table2_lemon, trace_bench)
from benchmarks import common
from benchmarks.common import all_benchmarks


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated benchmark names")
    ap.add_argument("--json", default=None)
    ap.add_argument("--quick", action="store_true",
                    help="small-scale defaults (CI smoke mode)")
    ap.add_argument("--profile", action="store_true",
                    help="cProfile mode for profile-aware benchmarks "
                         "(sim_bench): top-20 cumulative hotspots")
    args = ap.parse_args()
    common.QUICK = args.quick
    common.PROFILE = args.profile
    only = set(args.only.split(",")) if args.only else None
    if only:
        unknown = only - set(all_benchmarks())
        if unknown:
            names = "\n  ".join(sorted(all_benchmarks()))
            ap.error(f"unknown benchmark(s) {sorted(unknown)}; registered "
                     f"benchmarks:\n  {names}")

    t0 = time.time()
    results = {}
    n_warn = 0
    failures = []
    for name, fn in all_benchmarks().items():
        if only and name not in only:
            continue
        try:
            rep = fn()
            rep.print()
            results[name] = {
                "rows": [[k, str(v), n] for k, v, n in rep.rows],
                "checks": [[d, ok, det] for d, ok, det in rep.checks],
                "wall_s": rep.wall_s,
                "labels": rep.meta,
            }
            n_warn += sum(1 for _, ok, _ in rep.checks if not ok)
        except Exception as e:  # noqa: BLE001
            failures.append(name)
            print(f"\n=== {name} === ERROR: {type(e).__name__}: {e}")
            traceback.print_exc()
    total_checks = sum(len(r["checks"]) for r in results.values())
    passed = total_checks - n_warn
    wall = time.time() - t0
    print(f"\n{'='*70}")
    print(f"benchmarks: {len(results)} ran, {len(failures)} errored "
          f"({failures if failures else ''})")
    print(f"paper-claim checks: {passed}/{total_checks} passed, "
          f"{n_warn} warnings; total {wall:.0f}s")
    if args.json:
        out = {
            "meta": {
                "git_sha": common.git_sha(),
                "timestamp": time.strftime("%Y-%m-%dT%H:%M:%S"),
                "python": platform.python_version(),
                "machine": platform.machine(),
                "quick": args.quick,
                "wall_s": round(wall, 2),
            },
            "benchmarks": results,
        }
        with open(args.json, "w") as f:
            json.dump(out, f, indent=1)
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
