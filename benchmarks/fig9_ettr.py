"""Figure 9 / Observation 10: analytical E[ETTR] vs measured job runs."""
import numpy as np

from benchmarks.common import benchmark, get_sim
from repro.cluster import analysis
from repro.core import mttf_model
from repro.core.ettr_model import ETTRParams, expected_ettr
from repro.core.montecarlo import simulate_run_ettr


@benchmark("fig9_ettr")
def run(rep):
    # (1) analytic values for the paper's headline cases
    for gpus in (512, 1024, 2048, 4096):
        p = ETTRParams(n_nodes=gpus // 8, r_f=6.5e-3, w_cp_s=300, u0_s=300,
                       runtime_s=7 * 86400)
        rep.add(f"E[ETTR]@{gpus}gpu(w=5min)", round(expected_ettr(p), 3))
    rep.check("Obs 10: 2-4k GPU runs at ETTR ~0.85-0.9",
              0.83 <= expected_ettr(ETTRParams(
                  n_nodes=256, r_f=6.5e-3, w_cp_s=300, u0_s=300)) <= 0.92)
    # (2) Monte-Carlo agreement (paper: within ~5% at 8k GPUs)
    p8k = ETTRParams(n_nodes=1024, r_f=6.5e-3, w_cp_s=300, u0_s=300)
    mc = simulate_run_ettr(p8k, n_runs=300, seed=0)
    ana = expected_ettr(p8k)
    rep.add("analytic_vs_MC@8k", f"{ana:.4f} vs {mc.ettr_mean:.4f}")
    rep.check("analytic within 5% of Monte Carlo",
              abs(ana - mc.ettr_mean) / mc.ettr_mean < 0.05)
    # (3) measured job runs from the simulator vs expectation — Eq. 1 models
    # multi-tenant queue waits, so feed each run's observed q and R back in
    sim = get_sim("RSC-1", days=12.0)
    rf = mttf_model.fit_r_f(sim.records, min_gpus=64) or 6.5e-3
    # hourly checkpoints: the paper's typical interval for larger jobs
    rows = analysis.run_ettrs(sim.records, min_gpus=64, min_hours=12.0,
                              checkpoint_interval=3600.0,
                              r_f_per_node_day=rf)
    if rows:
        measured = float(np.mean([r.ettr for _, r in rows]))
        expects = []
        for g, r in rows:
            n_att = max(r.n_interruptions + 1, 1)
            # realized interruption rate (incl. preemptions the analytic
            # failure-only model does not see)
            run_days = max(r.wallclock - r.queue, 3600.0) / 86400.0
            rf_eff = max(r.n_interruptions / run_days / max(g // 8, 1), rf)
            expects.append(expected_ettr(ETTRParams(
                n_nodes=max(g // 8, 1), r_f=rf_eff, w_cp_s=300, u0_s=300,
                dt_cp_s=3600.0, q_s=r.queue / n_att,
                runtime_s=max(r.productive, 3600.0))))
        expect = float(np.mean(expects))
        rep.add("measured_job_run_ettr_mean", round(measured, 3),
                f"n={len(rows)}")
        rep.add("E[ETTR] at realized interruption rates", round(expect, 3))
        rep.check("measured ETTR tracks E[ETTR]; measured is the "
                  "conservative underestimate (paper Fig 9 note)",
                  measured <= expect + 0.1,
                  f"{measured:.3f} vs {expect:.3f}")
        # the paper's caveat: congested multi-tenant queues depress ETTR for
        # runs that are not highest-priority; report the queue share
        q_share = float(np.mean([r.queue / max(r.wallclock, 1e-9)
                                 for _, r in rows]))
        rep.add("queue_share_of_wallclock", round(q_share, 3),
                "large high-priority jobs see less (paper Fig 9 note)")
