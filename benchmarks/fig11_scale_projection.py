"""Figure 11 / §V: failure-model fit + MTTF scale projection from a seed
ensemble.

The paper's forward-looking claims — MTTF ~ 1.8 h at 16,384 GPUs and
~0.23 h (14 min) at 131,072 GPUs — come from fitting the r_f failure
model to measured cluster data and projecting MTTF = (N * r_f)^-1 out to
future scales.  This benchmark reproduces that pipeline statistically:
a 16-seed x 3-scale ensemble of full replays (under a minute on 8
cores), a per-cell r_f fit, and band checks that the injected rate and
the single-seed analytical ``ettr_model`` prediction fall inside the
ensemble bands before projecting to the paper's headline scales.
"""
import os
import time

import numpy as np

from benchmarks import common
from benchmarks.common import benchmark

R_F_INJECTED = 6.5e-3     # RSC-1 calibration (failures per node-day)

# Fault-model v2 scenario packs: fitted-r_f bands calibrated on the
# 8-seed x 4096-GPU x 8-day grid below (ensemble aggregation is
# bit-deterministic for a fixed seed set, so these are regression bands,
# not statistical guesses).  Measured means: independent 6.24e-3,
# rack-correlated 8.67e-3 (domain blasts add failures on top of the
# chains), slow-detection 7.49e-3.
SCENARIO_RF_BANDS = {
    "rack-correlated": (7.0e-3, 11.0e-3),
    "slow-detection": (6.0e-3, 9.5e-3),
}
SCENARIO_GPUS = 4096
SCENARIO_SEEDS = 8


def _bracket_backends():
    """Backends the oracle-bracketing checks run on: always the numpy
    reference, plus JAX_VMAP when jax imports here."""
    from repro.core.backend import jax_available

    return ["numpy"] + (["jax_vmap"] if jax_available() else [])


def _check_bracketing(rep, agg, scales, tag):
    """Oracle-bracketing contract (both backends): the batched
    analytical bands — one ``batch_bands`` call over the grid, fed each
    cell's fitted r_f — must bracket the engine ensemble's
    model-anchored ETTR band at every scale."""
    from repro.ensemble.run import batched_analytic_bands, oracle_bracket

    for bk in _bracket_backends():
        bands, res = batched_analytic_bands(agg, r_f_nominal=R_F_INJECTED,
                                            backend=bk)
        for g in scales:
            ok, eng_mean, ab = oracle_bracket(agg, bands, g)
            if ok is None:
                rep.check(f"{tag}: {g} GPUs batched bands ({bk}) bracket "
                          f"the engine ensemble", True,
                          "no qualifying runs to bracket (vacuous)")
                continue
            rep.check(f"{tag}: {g} GPUs batched bands ({bk}) bracket the "
                      f"engine ensemble",
                      bool(ok),
                      f"engine {eng_mean:.3f} vs batched "
                      f"[{ab.lo:.3f}, {ab.hi:.3f}] + calibration pads "
                      f"({res.n_compiled_calls} compiled call(s))")


@benchmark("fig11_scale_projection")
def run(rep):
    from repro.core.mttf_model import projected_mttf_hours
    from repro.ensemble.run import (MODEL_PAD_HI, MODEL_PAD_LO,
                                    analytic_ettr, run_ensemble)

    procs = min(os.cpu_count() or 1, 8)
    if common.QUICK:
        gpus, seeds, days, min_hours = [256, 512], 2, 2.0, 4.0
    else:
        gpus, seeds, days, min_hours = [1024, 4096, 16384], 16, 8.0, 12.0
    rep.label("grid", f"{seeds}seed_x_{len(gpus)}scale_{days:g}d")
    rep.label("procs", procs)

    t0 = time.time()
    agg = run_ensemble(gpus, range(seeds), horizon_days=days,
                       r_f=R_F_INJECTED, min_hours=min_hours, procs=procs)
    wall = time.time() - t0
    rep.add("ensemble_wall_s", round(wall, 2),
            f"{agg.n_cells} cells on {procs} procs")

    fitted_all = []
    for g in agg.scales():
        bands = agg.bands(g)
        b_rf = bands["fitted_r_f"]
        b_ettr = bands["ettr_model_nominal"]
        b_meas = bands["ettr_sim"]
        rep.add(f"{g}gpu.fitted_r_f_x1000",
                f"{b_rf.mean * 1000:.2f} [{b_rf.lo * 1000:.2f},"
                f"{b_rf.hi * 1000:.2f}] n={b_rf.n}",
                f"injected {R_F_INJECTED * 1000:.2f}")
        if b_meas.n:
            rep.add(f"{g}gpu.ettr_measured",
                    f"{b_meas.mean:.3f} [{b_meas.lo:.3f},{b_meas.hi:.3f}] "
                    f"n={b_meas.n}")
        fitted_all.extend(c.fitted_r_f for c in agg.cells_at(g)
                          if np.isfinite(c.fitted_r_f) and c.fitted_r_f > 0)
        if not common.QUICK:
            rep.check(
                f"{g} GPUs: injected r_f inside fitted ensemble band",
                b_rf.contains(R_F_INJECTED, pad_lo=0.3 * R_F_INJECTED,
                              pad_hi=0.3 * R_F_INJECTED),
                f"{R_F_INJECTED * 1000:.2f} vs [{b_rf.lo * 1000:.2f},"
                f"{b_rf.hi * 1000:.2f}] /1000 node-days")
            model = analytic_ettr(g, R_F_INJECTED)
            rep.check(
                f"{g} GPUs: analytical ettr_model prediction inside "
                f"ensemble band (PR-2 calibration pad)",
                b_ettr.contains(model, pad_lo=MODEL_PAD_LO,
                                pad_hi=MODEL_PAD_HI),
                f"{model:.3f} vs [{b_ettr.lo:.3f},{b_ettr.hi:.3f}]")

    if fitted_all:
        rf_fit = float(np.mean(fitted_all))
        rep.add("ensemble_fitted_r_f_x1000", round(rf_fit * 1000, 2),
                f"paper RSC-1: {R_F_INJECTED * 1000:.2f}, "
                f"n={len(fitted_all)} cells")
        p16k = projected_mttf_hours(16384, rf_fit)
        p131k = projected_mttf_hours(131072, rf_fit)
        rep.add("projection_16384gpu_h", round(p16k, 2), "paper: 1.8")
        rep.add("projection_131072gpu_h", round(p131k, 3), "paper: 0.23")
        if not common.QUICK:
            rep.check("fitted-rate 16,384-GPU MTTF projection within 2.5x "
                      "of the paper's 1.8 h", 1.8 / 2.5 < p16k < 1.8 * 2.5,
                      f"{p16k:.2f}h")
            rep.check("fitted-rate 131,072-GPU projection within 2.5x of "
                      "the paper's 0.23 h", 0.23 / 2.5 < p131k < 0.23 * 2.5,
                      f"{p131k:.3f}h")
    if common.QUICK:
        # toy-scale bracketing (tier-1): every named fault-model v2 pack
        # threads through the ensemble AND its batched analytical bands
        # bracket the engine ensemble band, on both backends
        from repro.configs.scenarios import available_scenarios

        _check_bracketing(rep, agg, gpus, "independent")
        for scen in available_scenarios():
            agg_s = run_ensemble([256], range(2), horizon_days=days,
                                 r_f=R_F_INJECTED, min_hours=min_hours,
                                 procs=1, scenario=scen)
            rep.check(f"{scen}: pack threads through the ensemble",
                      agg_s.n_cells == 2)
            _check_bracketing(rep, agg_s, [256], scen)

    if not common.QUICK:
        budget = 60.0 * max(1.0, 8.0 / procs)
        rep.check(f"16-seed x 3-scale ensemble within budget "
                  f"({budget:.0f}s at {procs} procs)", wall < budget,
                  f"{wall:.1f}s")

        # oracle-bracketing on the headline grid: the batched analytical
        # bands (one compiled call over the whole seed x scale grid, fed
        # the fitted rates) must bracket the engine ensemble at every
        # scale, on both backends
        _check_bracketing(rep, agg, agg.scales(), "independent")

        # fault-model v2 scenario packs: one mid-scale grid per pack
        # (all four named packs — None is the exact-legacy
        # independent-v1), fitted-rate means gated against the
        # calibrated bands above and batched bands bracketing the engine
        scen_means = {}
        for scen in (None, "lablup-504", *sorted(SCENARIO_RF_BANDS)):
            agg_s = run_ensemble([SCENARIO_GPUS], range(SCENARIO_SEEDS),
                                 horizon_days=days, r_f=R_F_INJECTED,
                                 min_hours=min_hours, procs=procs,
                                 scenario=scen)
            b = agg_s.bands(SCENARIO_GPUS)["fitted_r_f"]
            name = scen or "independent"
            scen_means[name] = b.mean
            rep.add(f"scenario.{name}.fitted_r_f_x1000",
                    f"{b.mean * 1000:.2f} [{b.lo * 1000:.2f},"
                    f"{b.hi * 1000:.2f}] n={b.n}")
            if scen in SCENARIO_RF_BANDS:
                lo, hi = SCENARIO_RF_BANDS[scen]
                rep.check(f"{scen}: fitted r_f inside calibrated "
                          "scenario band",
                          lo <= b.mean <= hi,
                          f"{b.mean * 1000:.2f} vs [{lo * 1000:.2f},"
                          f"{hi * 1000:.2f}] /1000 node-days")
            _check_bracketing(rep, agg_s, [SCENARIO_GPUS], name)
        rep.check("rack-correlated raises the fitted failure rate above "
                  "the independent chains (same seeds)",
                  scen_means["rack-correlated"]
                  > scen_means["independent"],
                  f"{scen_means['rack-correlated'] * 1000:.2f} vs "
                  f"{scen_means['independent'] * 1000:.2f}")
