"""Figure 10: 12k-GPU ETTR contours over (failure rate x checkpoint
write overhead), Daly-Young intervals."""
import numpy as np

from benchmarks.common import benchmark
from repro.core.ettr_model import (ETTRParams, ettr_contour, expected_ettr,
                                   required_w_cp_for_target)


@benchmark("fig10_contours")
def run(rep):
    r_grid, w_grid, E, DT = ettr_contour(n_gpus=12_288)
    rep.add("grid", f"{E.shape[0]}x{E.shape[1]} (w_cp x r_f)")
    # the paper's operating point and its two escape routes
    base = expected_ettr(ETTRParams(n_nodes=1536, r_f=6.5e-3, w_cp_s=300,
                                    u0_s=300))
    fast_ckpt = expected_ettr(ETTRParams(n_nodes=1536, r_f=6.5e-3,
                                         w_cp_s=10, u0_s=300))
    low_rf = expected_ettr(ETTRParams(n_nodes=1536, r_f=1.0e-3,
                                      w_cp_s=300, u0_s=300))
    rep.add("ETTR@12k(r_f=6.5, w=5min)", round(base, 3), "poor")
    rep.add("ETTR@12k(r_f=6.5, w=10s)", round(fast_ckpt, 3),
            "async checkpointing")
    rep.add("ETTR@12k(r_f=1.0, w=5min)", round(low_rf, 3),
            "reliability improvement")
    rep.check("Fig 10: base point below 0.8", base < 0.80)
    rep.check("Fig 10: O(10 s) checkpoints recover ETTR>=0.9",
              fast_ckpt >= 0.90)
    rep.check("Fig 10: r_f ~1/1000 node-days recovers ETTR~0.9",
              low_rf >= 0.88)
    w_req = required_w_cp_for_target(12_288, 0.90, 6.5e-3)
    rep.add("required w_cp for ETTR 0.9 @ 12k GPUs", f"{w_req:.1f} s",
            "paper: O(10 s)")
    rep.check("required write overhead is O(10 s)", 3 <= w_req <= 60)
    # red region of Fig 10: Daly-Young intervals below 10 s are impractical
    frac_red = float((DT < 10.0).mean())
    rep.add("fraction of grid with dt* < 10 s", round(frac_red, 3))
