"""Figure 11 / Table II / Observation 11: lemon-node detection."""
import numpy as np

from benchmarks.common import benchmark, get_sim
from repro.cluster import analysis
from repro.cluster.scheduler import ClusterSim
from repro.cluster.workload import ClusterSpec
from repro.core.lemon import (LEMON_ROOT_CAUSES, LemonDetector,
                              LemonThresholds, NodeHistory, SIGNALS,
                              detection_quality)


@benchmark("table2_lemon")
def run(rep):
    # (1) detection quality on a 28-day synthetic fleet snapshot (Fig 11)
    rng = np.random.default_rng(0)
    lemons = set(range(24))  # 1.2% of 2000 nodes, as on RSC-1
    hists = []
    for i in range(2000):
        h = NodeHistory(i)
        if i in lemons:
            h.xid_cnt = int(rng.poisson(6))
            h.tickets = int(rng.poisson(3))
            h.out_count = int(rng.poisson(5))
            h.multi_node_node_fails = int(rng.poisson(5))
            h.single_node_node_fails = int(rng.poisson(3))
            h.single_node_jobs = max(1, int(rng.poisson(4)))
            h.excl_jobid_count = int(rng.poisson(10))
        else:
            h.xid_cnt = int(rng.random() < 0.05)
            h.out_count = int(rng.random() < 0.1)
            h.excl_jobid_count = int(rng.poisson(0.5))
            h.single_node_jobs = int(rng.poisson(30))
        hists.append(h)
    q = detection_quality(LemonDetector().scan(hists), lemons)
    rep.add("fleet", "2000 nodes, 24 true lemons (1.2%)")
    for k in ("flagged", "tp", "fp", "precision", "recall"):
        rep.add(f"detector.{k}", round(q[k], 3) if isinstance(q[k], float)
                else q[k])
    rep.check("Obs 11: >85% detection accuracy (paper: >85%)",
              q["precision"] >= 0.85, f"precision {q['precision']:.2f}")
    # excl_jobid_count is weakly correlated (paper Fig 11)
    excl_only = NodeHistory(9999)
    excl_only.excl_jobid_count = 40
    rep.check("user exclusions alone never flag a lemon",
              not LemonDetector().evaluate(excl_only).is_lemon)

    # (2) Table II root causes
    for cause, frac in sorted(LEMON_ROOT_CAUSES.items(), key=lambda kv: -kv[1]):
        rep.add(f"root_cause.{cause}", frac)
    rep.check("GPU+DIMM+PCIE are the top root causes (Table II)",
              LEMON_ROOT_CAUSES["GPU"] >= 0.28
              and LEMON_ROOT_CAUSES["DIMM"] >= 0.20)

    # (3) end-to-end mitigation: large-job failure rate with/without removal
    spec = ClusterSpec("RSC-1", n_nodes=300, jobs_per_day=1300,
                       target_utilization=0.83, r_f=6.5e-3,
                       lemon_fraction=0.04, lemon_rate_multiplier=100.0)
    det = LemonDetector(LemonThresholds(
        xid_cnt=2, tickets=1, out_count=2, multi_node_node_fails=1,
        single_node_node_fails=1, min_signals=2))
    f0s, f1s, removed = [], [], 0
    for seed in (0, 7):
        base = ClusterSim(spec, horizon_days=7.0, seed=seed)
        base.run()
        mit = ClusterSim(spec, horizon_days=7.0, seed=seed,
                         enable_lemon_detection=True,
                         lemon_scan_period_days=1.0, lemon_detector=det)
        mit.run()
        f0s.append(analysis.large_job_failure_rate(base.records, 128))
        f1s.append(analysis.large_job_failure_rate(mit.records, 128))
        removed += len(mit.lemon_removal_log)
    rep.add("large_job_failure_rate.baseline", round(float(np.mean(f0s)), 4),
            "paper: 14%")
    rep.add("large_job_failure_rate.with_lemon_removal",
            round(float(np.mean(f1s)), 4), "paper: 4%")
    rep.add("lemons_removed", removed, "paper: 40 fleet-wide")
    rep.check("lemon removal reduces large-job failure rate (Obs 11)",
              np.mean(f1s) <= np.mean(f0s) + 0.01)
