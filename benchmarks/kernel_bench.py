"""Kernel-path microbenchmarks (CPU wall-time): flash/blockwise attention vs
naive oracle, associative-scan RG-LRU vs sequential, measured us/call."""
import time

import jax
import jax.numpy as jnp

from benchmarks.common import benchmark
from repro.kernels import ops, ref


def _time(fn, *args, iters=3):
    # single warmup call (compile + dispatch once)
    out = fn(*args)
    (out[0] if isinstance(out, tuple) else out).block_until_ready()
    t0 = time.time()
    for _ in range(iters):
        out = fn(*args)
        (out[0] if isinstance(out, tuple) else out).block_until_ready()
    return (time.time() - t0) / iters * 1e6


@benchmark("kernel_bench")
def run(rep):
    key = jax.random.PRNGKey(0)
    B, S, H, KV, D = 1, 2048, 8, 4, 64
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (B, S, H, D), jnp.float32)
    k = jax.random.normal(ks[1], (B, S, KV, D), jnp.float32)
    v = jax.random.normal(ks[2], (B, S, KV, D), jnp.float32)

    flash = jax.jit(lambda q, k, v: ops._flash(
        q, k, v, True, 0, 0, 0.0, 0, 512, 512))
    naive = jax.jit(lambda q, k, v: ref.attention_ref(q, k, v, causal=True))
    t_flash = _time(flash, q, k, v)
    t_naive = _time(naive, q, k, v)
    rep.add("attention.blockwise_us", round(t_flash))
    rep.add("attention.naive_us", round(t_naive))
    rep.add("attention.naive/blockwise", round(t_naive / t_flash, 2))

    # windowed attention: banded gather should beat rectangular by ~S/W
    win = jax.jit(lambda q, k, v: ops._flash(
        q, k, v, True, 256, 0, 0.0, 0, 256, 256))
    t_win = _time(win, q, k, v)
    rep.add("attention.sliding_window_us", round(t_win))
    rep.check("banded local attention beats full causal",
              t_win < t_flash)

    # RG-LRU: associative scan vs sequential reference
    Bw, Sw, W = 2, 2048, 256
    x = jax.random.normal(ks[0], (Bw, Sw, W), jnp.float32)
    la = -jax.nn.softplus(jax.random.normal(ks[1], (Bw, Sw, W)))
    par = jax.jit(lambda x, la: ops.rglru(x, la)[0])
    seq = jax.jit(lambda x, la: ref.rglru_ref(x, la)[0])
    t_par = _time(par, x, la)
    t_seq = _time(seq, x, la)
    rep.add("rglru.assoc_scan_us", round(t_par))
    rep.add("rglru.sequential_us", round(t_seq))
    rep.add("rglru.note", "assoc-scan is the TPU-preferred form "
            "(O(log S) depth); on 1 CPU core it trades ~2x work")
    rep.check("assoc-scan within 3x of sequential on CPU",
              t_par < 3 * t_seq)
