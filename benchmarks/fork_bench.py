"""Fork-plan speedup: prefix-sharing sweeps vs cold-start replays.

The paper's mitigation what-ifs (§IV) replay the same cluster under many
policies; the fork plan (``repro.mitigations.forkplan``) runs the shared
baseline prefix once per (scale, seed) and forks each policy cell at its
first intervention.  This benchmark runs the checkpoint-cadence what-if
grid (paper §II-D / Fig. 10 — 3 policies x 3 scales x seeds at a
multi-month horizon) both ways through ``repro.mitigations.sweep`` and
reports:

  * ``fork_cells_per_sec`` — the gated throughput row (``--compare``
    fails on a >20% drop);
  * ``grid_speedup_x`` — whole-grid wall ratio (bounded by
    n_policies: the probe is itself one full replay per group);
  * ``policy_cell_speedup_x`` — the marginal ratio on non-probe cells
    (sum of cold walls over sum of forked/shared walls), the >=5x
    acceptance target: cadence policies are engine-inert, so their
    cells score straight off the probe trace;

plus a mixed grid with a mutating policy (``lemon_eviction``) reported
for context — an early diverger pays most of the horizon back, which is
exactly what the escape hatch and the marginal metric make visible.

Quick mode shrinks to 2 scales x 2 seeds x 4 days and additionally
asserts fork-vs-cold ``CellResult`` equality (tier-1 pytest smoke; the
full equality matrix lives in tests/test_forking.py).
"""
import time

from benchmarks import common
from benchmarks.common import benchmark

# acceptance (ISSUE 9): non-probe policy cells >=5x cheaper under the
# fork plan on the cadence what-if grid
ACCEPT_POLICY_CELL_SPEEDUP = 5.0

CADENCE_POLICIES = ("baseline", "checkpoint_fixed", "checkpoint_optimal")
# per-cell wall floor (s) when summing fork-side walls: shared cells
# round to 0.00 and would divide out to infinity
_WALL_FLOOR_S = 0.005


def _run_grid(policies, gpus, seeds, days, *, fork):
    from repro.mitigations.sweep import sweep

    t0 = time.time()
    res = sweep(policies=policies, gpus_list=gpus, seeds=range(seeds),
                horizon_days=days, procs=0, fork=fork)
    return res, time.time() - t0


def _noncarrier_wall(cells):
    """Sum of cell walls excluding the probe-carrying (or baseline) cell
    of each (scale, seed) group, floored per cell at _WALL_FLOOR_S."""
    total = 0.0
    for c in cells:
        fk = c.extra.get("fork")
        if fk is not None:
            if fk.get("carries_probe"):
                continue
        elif c.policy == "baseline":
            continue
        total += max(c.wall_s, _WALL_FLOOR_S)
    return total


def _strip(cell):
    d = {k: v for k, v in cell.__dict__.items() if k != "wall_s"}
    d["extra"] = {k: v for k, v in cell.extra.items() if k != "fork"}
    return d


@benchmark("fork_bench")
def run(rep):
    if common.QUICK:
        gpus, seeds, days = [256, 512], 2, 4.0
    else:
        gpus, seeds, days = [512, 2048, 8192], 2, 60.0
    rep.label("grid", f"{len(CADENCE_POLICIES)}pol_x_{len(gpus)}scale_"
                      f"x_{seeds}seed_{days:g}d")

    fork_res, fork_wall = _run_grid(CADENCE_POLICIES, gpus, seeds, days,
                                    fork=True)
    cold_res, cold_wall = _run_grid(CADENCE_POLICIES, gpus, seeds, days,
                                    fork=False)
    n_cells = len(fork_res.cells)
    marginal = (_noncarrier_wall(cold_res.cells)
                / _noncarrier_wall(fork_res.cells))
    n_shared = sum(1 for c in fork_res.cells
                   if c.extra.get("fork", {}).get("mode") == "shared")
    n_forked = n_cells - n_shared
    n_snaps = sum(c.extra["fork"].get("n_snapshots", 0)
                  for c in fork_res.cells if "fork" in c.extra)
    rep.add("grid_cells", n_cells)
    rep.add("fork_wall_s", round(fork_wall, 2))
    rep.add("cold_wall_s", round(cold_wall, 2))
    rep.add("fork_cells_per_sec",
            round(n_cells / max(fork_wall, 1e-9), 2))
    rep.add("cold_cells_per_sec",
            round(n_cells / max(cold_wall, 1e-9), 2))
    rep.add("grid_speedup_x", round(cold_wall / max(fork_wall, 1e-9), 2),
            f"bounded by n_policies={len(CADENCE_POLICIES)}")
    rep.add("policy_cell_speedup_x", round(marginal, 1),
            "non-probe cells: cold walls / forked+shared walls")
    rep.add("n_shared_cells", n_shared)
    rep.add("n_forked_cells", n_forked)
    rep.add("n_probe_snapshots", n_snaps,
            "cadence grid is engine-inert: snapshots stop after t=0")
    rep.check("every grid cell completed",
              n_cells == len(CADENCE_POLICIES) * len(gpus) * seeds
              and len(cold_res.cells) == n_cells,
              f"{n_cells} fork / {len(cold_res.cells)} cold")

    if common.QUICK:
        # tier-1 smoke: fork and cold grids must agree cell for cell
        fk = sorted((_strip(c) for c in fork_res.cells),
                    key=lambda d: (d["n_gpus"], d["policy"], d["seed"]))
        cd = sorted((_strip(c) for c in cold_res.cells),
                    key=lambda d: (d["n_gpus"], d["policy"], d["seed"]))
        rep.check("fork cells == cold cells (wall/provenance aside)",
                  fk == cd, f"{n_cells} cells")
    else:
        rep.check(
            f"policy cells >={ACCEPT_POLICY_CELL_SPEEDUP:.0f}x cheaper "
            f"under the fork plan", marginal >= ACCEPT_POLICY_CELL_SPEEDUP,
            f"{marginal:.1f}x")

        # context: a mutating policy mix (lemon forks mid-run and pays
        # its divergent suffix) — reported, not gated
        mixed = ("baseline", "checkpoint_optimal", "lemon_eviction")
        mf, mf_wall = _run_grid(mixed, [gpus[0]], seeds, days, fork=True)
        mc, mc_wall = _run_grid(mixed, [gpus[0]], seeds, days, fork=False)
        rep.add("mixed_grid_speedup_x",
                round(mc_wall / max(mf_wall, 1e-9), 2),
                f"{'+'.join(mixed)} at {gpus[0]} GPUs")
