"""Figure 12 / Observation 12: adaptive routing on the TPU-torus fabric."""
from benchmarks.common import benchmark
from repro.fabric.simulate import contention_experiment, link_error_experiment


@benchmark("fig12_adaptive_routing")
def run(rep):
    # (a) 512-GPU all-reduce under injected link errors, 5 iterations
    a = link_error_experiment(n_iterations=5, seed=0).summary()
    rep.add("link_errors.static_bw(frac of link)", round(a["static_mean"], 4))
    rep.add("link_errors.adaptive_bw", round(a["adaptive_mean"], 4))
    rep.add("link_errors.adaptive_gain", round(a["adaptive_gain"], 2))
    rep.check("Obs 12: static routing loses >50% of bandwidth under errors",
              a["static_mean"] < 0.5 * a["adaptive_mean"],
              f"gain {a['adaptive_gain']:.2f}x")
    # (b) 32 concurrent 16-GPU all-reduces (contention)
    b = contention_experiment(seed=1).summary()
    rep.add("contention.static_mean", round(b["static_mean"], 3))
    rep.add("contention.static_std", round(b["static_std"], 3))
    rep.add("contention.adaptive_mean", round(b["adaptive_mean"], 3))
    rep.add("contention.adaptive_std", round(b["adaptive_std"], 3))
    rep.check("AR: higher mean, lower variance under contention (Fig 12b)",
              b["adaptive_mean"] >= 0.95 * b["static_mean"]
              and b["adaptive_std"] <= 1.1 * b["static_std"])
