"""Figure 4: attributed hardware failures per GPU-hour by symptom.

Trace-driven: rates and denominators come from each cluster's recorded
trace (jobs + faults tables and meta), not from the live sim object."""
from benchmarks.common import benchmark, get_trace
from repro.cluster import analysis


@benchmark("fig4_attribution")
def run(rep):
    for cluster in ("RSC-1", "RSC-2"):
        trace = get_trace(cluster)
        rates = analysis.attribution_rates(trace)
        for sym, rate in list(rates.items())[:8]:
            rep.add(f"{cluster}.{sym}", f"{rate:.3e} /GPU-h")
        top4 = set(list(rates)[:4])
        rep.check(
            f"{cluster}: IB links / mounts / GPU memory / PCIe dominate "
            "(Obs 5)",
            len(top4 & {"ib_link_error", "filesystem_mount",
                        "gpu_memory_errors", "pcie_errors",
                        "gpu_unavailable"}) >= 2,
            ",".join(top4))
    # fault-model v2 columns: summary degrades to {} on v1 traces (no
    # domain/detected_t columns) instead of raising, so this section is
    # schema-version-proof
    v2 = analysis.domain_detection_summary(get_trace("RSC-1"))
    for k, v in v2.items():
        rep.add(f"RSC-1.v2.{k}", str(v))
    rep.check("v2 summary degrades gracefully (dict, never KeyError)",
              isinstance(v2, dict),
              "empty on v1/legacy traces" if not v2 else f"{len(v2)} keys")

    t1 = get_trace("RSC-1")
    t2 = get_trace("RSC-2")
    r1 = t1.n_rows("faults") / (t1.n_nodes * t1.horizon_days)
    r2 = t2.n_rows("faults") / (t2.n_nodes * t2.horizon_days)
    rep.add("RSC-1 node failure rate /1000 node-days", round(r1 * 1000, 2),
            "paper: 6.50")
    rep.add("RSC-2 node failure rate /1000 node-days", round(r2 * 1000, 2),
            "paper: 2.34")
    rep.check("RSC-1 less reliable than RSC-2 (paper: 6.50 vs 2.34)",
              r1 > 1.5 * r2)
