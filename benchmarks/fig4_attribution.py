"""Figure 4: attributed hardware failures per GPU-hour by symptom."""
from benchmarks.common import benchmark, get_sim
from repro.cluster import analysis


@benchmark("fig4_attribution")
def run(rep):
    for cluster in ("RSC-1", "RSC-2"):
        sim = get_sim(cluster)
        rates = analysis.attribution_rates(
            sim.records, sim.fault_log, sim.spec.n_gpus, sim.horizon_s)
        for sym, rate in list(rates.items())[:8]:
            rep.add(f"{cluster}.{sym}", f"{rate:.3e} /GPU-h")
        top4 = set(list(rates)[:4])
        rep.check(
            f"{cluster}: IB links / mounts / GPU memory / PCIe dominate "
            "(Obs 5)",
            len(top4 & {"ib_link_error", "filesystem_mount",
                        "gpu_memory_errors", "pcie_errors",
                        "gpu_unavailable"}) >= 2,
            ",".join(top4))
    s1 = get_sim("RSC-1")
    s2 = get_sim("RSC-2")
    r1 = len(s1.fault_log) / (s1.spec.n_nodes * s1.horizon_s / 86400)
    r2 = len(s2.fault_log) / (s2.spec.n_nodes * s2.horizon_s / 86400)
    rep.add("RSC-1 node failure rate /1000 node-days", round(r1 * 1000, 2),
            "paper: 6.50")
    rep.add("RSC-2 node failure rate /1000 node-days", round(r2 * 1000, 2),
            "paper: 2.34")
    rep.check("RSC-1 less reliable than RSC-2 (paper: 6.50 vs 2.34)",
              r1 > 1.5 * r2)
