"""Figure 6 / Observation 7: job-size distribution vs GPU-time share."""
from benchmarks.common import benchmark, get_sim
from repro.cluster.workload import MIXES


@benchmark("fig6_job_mix")
def run(rep):
    for cluster, mix in MIXES.items():
        small_jobs = sum(f for s, (f, _) in mix.items() if s <= 8)
        small_time = sum(sh for s, (_, sh) in mix.items() if s <= 8)
        big_time = sum(sh for s, (_, sh) in mix.items() if s >= 256)
        rep.add(f"{cluster}.jobs<=8gpu", round(small_jobs, 3), "paper: >0.90")
        rep.add(f"{cluster}.gpu_time<=8gpu", round(small_time, 3),
                "paper: <0.10")
        rep.add(f"{cluster}.gpu_time>=256gpu", round(big_time, 3),
                "paper: 0.66 / 0.52")
        rep.check(f"{cluster}: Obs 7 (90% small jobs, <10% of time)",
                  small_jobs >= 0.90 and small_time <= 0.30)
    f4k, s4k = MIXES["RSC-1"][4096]
    rep.add("RSC-1.jobs_4096gpu", f4k, "paper: <1%")
    rep.add("RSC-1.gpu_time_4096gpu", s4k, "paper: 12%")
    rep.check("4k-GPU jobs <1% of jobs, ~12% of GPU time",
              f4k < 0.01 and abs(s4k - 0.12) < 0.02)
    # realized mix from the simulator matches the target tables
    sim = get_sim("RSC-1")
    n = len({r.run_id for r in sim.records})
    small = len({r.run_id for r in sim.records if r.n_gpus <= 8})
    rep.add("sim.realized_jobs<=8gpu", round(small / n, 3))
    rep.check("simulator reproduces the size mix", small / n >= 0.85)
