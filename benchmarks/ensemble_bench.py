"""Ensemble-runner throughput: cluster-days simulated per wall-second.

AIReSim-style figure of merit for a reliability simulator: how much
simulated cluster time the ensemble engine sustains per second of wall
clock.  Runs the acceptance grid — 16 seeds x {1024, 4096, 16384} GPUs x
8 days — through ``repro.ensemble`` on a worker pool, reports cells/sec,
RSC-1-equivalent cluster-days/sec, and pool efficiency, and proves the
determinism contract: the aggregated bands from a 1-worker and a
multi-worker run of the same small grid are bit-identical.

Quick mode shrinks to a 2-scale x 2-seed x 1.5-day grid (tier-1 pytest
smoke).
"""
import os
import time

from benchmarks import common
from benchmarks.common import benchmark

# acceptance target (ISSUE 4): the 16-seed x 3-scale x 8-day grid in under
# a minute on 8 cores; allowance scales when fewer cores are available
ACCEPT_WALL_S_8CORES = 60.0


def _grid_json(gpus, seeds, days, procs, min_hours=12.0):
    import json

    from repro.ensemble.run import run_ensemble

    agg = run_ensemble(gpus, range(seeds), horizon_days=days,
                       procs=procs, min_hours=min_hours)
    # "scales" only: bands + attribution (cell wall_s is machine noise);
    # serialized so NaN bands (no qualifying runs) compare equal
    return agg, json.dumps(agg.to_json()["scales"], sort_keys=True)


@benchmark("ensemble_bench")
def run(rep):
    procs = min(os.cpu_count() or 1, 8)
    if common.QUICK:
        gpus, seeds, days, min_hours = [256, 512], 2, 1.5, 4.0
        det_gpus, det_seeds, det_days = [256, 512], 2, 1.0
    else:
        gpus, seeds, days, min_hours = [1024, 4096, 16384], 16, 8.0, 12.0
        det_gpus, det_seeds, det_days = [512, 1024], 2, 2.0
    rep.label("grid", f"{seeds}seed_x_{len(gpus)}scale_{days:g}d")
    rep.label("procs", procs)

    t0 = time.time()
    agg, _ = _grid_json(gpus, seeds, days, procs, min_hours)
    wall = time.time() - t0
    n_cells = agg.n_cells
    serial_s = sum(c.wall_s for g in agg.scales() for c in agg.cells_at(g))
    cluster_days = agg.rsc1_cluster_days()
    rep.add("grid_cells", n_cells)
    rep.add("wall_s", round(wall, 2), f"{procs} procs")
    rep.add("cells_per_sec", round(n_cells / max(wall, 1e-9), 2))
    rep.add("rsc1_cluster_days_per_sec",
            round(cluster_days / max(wall, 1e-9), 2),
            "AIReSim-style figure of merit")
    rep.add("pool_efficiency",
            round(serial_s / max(wall * procs, 1e-9), 2),
            f"sum(cell wall)={serial_s:.1f}s over {procs} procs")
    rep.check("every grid cell completed", n_cells == len(gpus) * seeds,
              f"{n_cells}/{len(gpus) * seeds}")
    budget = ACCEPT_WALL_S_8CORES * max(1.0, 8.0 / procs)
    rep.check(
        f"acceptance grid within budget ({budget:.0f}s at {procs} procs)",
        wall < budget, f"{wall:.1f}s")

    # determinism: same small grid, 1 worker vs a pool, any completion
    # order -> bit-identical aggregated bands (tests/test_ensemble.py
    # gates this; the benchmark proves it at the CLI layer too)
    _, bands1 = _grid_json(det_gpus, det_seeds, det_days, 1)
    _, bandsN = _grid_json(det_gpus, det_seeds, det_days, max(2, procs))
    rep.check("bands bit-identical across worker counts", bands1 == bandsN,
              f"{det_seeds}x{len(det_gpus)} grid, 1 vs {max(2, procs)} "
              f"workers")
