"""Live runtime validation: measured ETTR from the fault-tolerant trainer
under Poisson fault injection vs the analytical estimator — the closed loop
between the paper's model (C4) and an executing system."""
import shutil
import tempfile
import time

from benchmarks.common import benchmark
from repro.configs.base import get_arch, smoke_config
from repro.runtime.fault_injection import FaultInjector
from repro.runtime.train_loop import FaultTolerantTrainer, TrainerConfig


@benchmark("runtime_ettr")
def run(rep):
    cfg = smoke_config(get_arch("rsc-llm"))
    tmp = tempfile.mkdtemp(prefix="repro_bench_ckpt_")
    try:
        inj = FaultInjector(rate_per_step=0.04, n_nodes=8, seed=1)
        tcfg = TrainerConfig(total_steps=60, global_batch=4, seq_len=32,
                             ckpt_dir=tmp, ckpt_every_steps=5,
                             ckpt_async=True, n_nodes=8, seed=1)
        t0 = time.time()
        report = FaultTolerantTrainer(cfg, tcfg, inj).run()
        rep.add("steps_completed", report.final_step)
        rep.add("attempts", len(report.attempts))
        rep.add("faults_injected", len(inj.injected))
        rep.add("measured_ettr", round(report.measured_ettr, 3))
        rep.add("checkpoint_block_s", round(report.checkpoint_block_s, 2))
        rep.add("restart_overhead_s", round(report.restart_overhead_s, 2))
        rep.add("lost_work_s", round(report.lost_step_wall_s, 2))
        rep.add("wall_s", round(time.time() - t0, 1))
        rep.add("loss_first_to_last",
                f"{report.losses[0]:.3f} -> {report.losses[-1]:.3f}")
        rep.check("run completes despite injected faults",
                  report.final_step == 60)
        rep.check("training makes progress (loss decreases)",
                  report.losses[-1] < report.losses[0])
        rep.check("failures only cost unproductive time (ETTR < 1)",
                  0.3 <= report.measured_ettr < 1.0)
        if report.lemon_verdicts:
            rep.add("lemons_flagged",
                    [v.node_id for v in report.lemon_verdicts])
    finally:
        shutil.rmtree(tmp, ignore_errors=True)
