"""Figure 7: MTTF vs job size (Gamma CIs) + theory line + projections."""
from benchmarks.common import benchmark, get_sim
from repro.core import mttf_model


@benchmark("fig7_mttf")
def run(rep):
    sim = get_sim("RSC-1", days=12.0)
    rf = mttf_model.fit_r_f(sim.records, min_gpus=64)
    rep.add("fitted r_f /1000 node-days", round(rf * 1000, 2),
            "paper RSC-1: 6.50")
    curve = mttf_model.empirical_mttf_curve(sim.records)
    for p in curve:
        if p.n_gpus in (8, 64, 256, 512, 1024, 2048, 4096) \
                and p.n_failures > 0:
            theory = mttf_model.projected_mttf_hours(
                p.n_gpus, rf if rf > 0 else 6.5e-3)
            rep.add(f"mttf_{p.n_gpus}gpu_h",
                    f"{p.mttf_hours:.1f} [CI {p.ci_lo_hours:.1f},"
                    f"{p.ci_hi_hours:.1f}] n={p.n_failures}",
                    f"theory {theory:.1f}")
    # MTTF ~ 1/N: check ratio between adjacent large sizes on sim data
    big = {p.n_gpus: p for p in curve
           if p.n_gpus >= 256 and p.n_failures >= 3}
    sizes = sorted(big)
    inv_ok = all(
        0.2 < (big[a].mttf_hours / big[b].mttf_hours) / (b / a) < 5.0
        for a, b in zip(sizes, sizes[1:]))
    rep.check("Obs 8: MTTF decreases ~1/N_gpus for large jobs",
              inv_ok or len(sizes) < 2)
    # paper projections at the published r_f
    p16k = mttf_model.projected_mttf_hours(16384, 6.50e-3)
    p131k = mttf_model.projected_mttf_hours(131072, 6.50e-3)
    rep.add("projection_16384gpu_h", round(p16k, 2), "paper: 1.8")
    rep.add("projection_131072gpu_h", round(p131k, 3), "paper: 0.23")
    rep.check("16,384-GPU projection = 1.8 h", abs(p16k - 1.8) < 0.1)
    rep.check("131,072-GPU projection = 0.23 h", abs(p131k - 0.23) < 0.01)
    rep.check("fitted r_f within 3x of injected rate",
              rf == 0 or 0.33 * sim.spec.r_f < rf < 3 * sim.spec.r_f,
              f"{rf*1000:.2f} vs {sim.spec.r_f*1000:.2f}")
