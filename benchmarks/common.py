"""Shared benchmark harness: one module per paper figure/table.

Each benchmark returns a list of (name, value, derived) rows and optionally
asserts paper headline numbers (a failed expectation prints WARN rather than
crashing the suite — benchmarks are reports, tests are gates)."""
from __future__ import annotations

import functools
import json
import time
from dataclasses import dataclass, field


@dataclass
class Report:
    name: str
    rows: list = field(default_factory=list)
    checks: list = field(default_factory=list)
    wall_s: float = 0.0
    # benchmark-declared metadata for the --json trajectory file: scale
    # labels ("2000n_5d", "16seed_x_3scale"), modes, machine-relevant knobs
    meta: dict = field(default_factory=dict)

    def add(self, key: str, value, note: str = "") -> None:
        self.rows.append((key, value, note))

    def label(self, key: str, value) -> None:
        """Attach a scale/config label to the report (lands in --json)."""
        self.meta[key] = value

    def check(self, desc: str, ok: bool, detail: str = "") -> None:
        self.checks.append((desc, bool(ok), detail))

    def print(self) -> None:
        print(f"\n=== {self.name} ({self.wall_s:.1f}s) ===")
        for key, value, note in self.rows:
            v = f"{value:.6g}" if isinstance(value, float) else value
            print(f"  {key:58s} {v}{('  # ' + note) if note else ''}")
        for desc, ok, detail in self.checks:
            tag = "PASS" if ok else "WARN"
            print(f"  [{tag}] {desc}{('  (' + detail + ')') if detail else ''}")


_REGISTRY: dict[str, callable] = {}

# set by `benchmarks.run --quick`: benchmarks that support it drop to
# small-scale defaults (used by CI/tier-1 tests to catch API/perf-path
# regressions without paying full-scale wall time)
QUICK = False

# set by `benchmarks.run --profile`: natively profile-aware benchmarks
# (registered with native_profile=True, e.g. sim_bench) run one
# representative workload under cProfile and print the top cumulative
# hotspots instead of the full timing grid; every other benchmark is
# wrapped in a generic cProfile pass by benchmarks.run
PROFILE = False


def peak_rss_mb() -> float:
    """This process's peak RSS high-water mark in MB — recorded in the
    --json perf trajectory so the constant-memory claims (hot-path v3
    spill mode) are tracked alongside throughput."""
    import resource

    return resource.getrusage(resource.RUSAGE_SELF).ru_maxrss / 1024.0


def git_sha() -> str:
    """Current commit (+ '-dirty' when the tree has changes); '?' outside
    a git checkout — recorded in --json so perf points are attributable."""
    import os
    import subprocess

    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    try:
        sha = subprocess.run(
            ["git", "rev-parse", "--short", "HEAD"], cwd=root, timeout=10,
            capture_output=True, text=True).stdout.strip() or "?"
        dirty = subprocess.run(
            ["git", "status", "--porcelain"], cwd=root, timeout=10,
            capture_output=True, text=True).stdout.strip()
        return sha + ("-dirty" if dirty else "")
    except Exception:  # noqa: BLE001 — no git / not a checkout
        return "?"


def benchmark(name: str, *, native_profile: bool = False):
    """Register a benchmark.  ``native_profile=True`` marks it as
    handling ``--profile`` itself (reading ``common.PROFILE`` and running
    its own cProfile pass, like sim_bench); the rest get a generic
    cProfile wrap from ``benchmarks.run`` when profiled."""
    def deco(fn):
        @functools.wraps(fn)
        def wrapper(*a, **kw):
            rep = Report(name)
            t0 = time.time()
            fn(rep, *a, **kw)
            rep.wall_s = time.time() - t0
            return rep
        wrapper.native_profile = native_profile
        _REGISTRY[name] = wrapper
        return wrapper
    return deco


def profile_call(name: str, fn):
    """Generic ``--profile`` path for benchmarks that are not natively
    profile-aware: run the whole benchmark under cProfile, print the
    top-20 cumulative hotspots, and stamp the report."""
    import cProfile
    import io
    import pstats

    prof = cProfile.Profile()
    prof.enable()
    try:
        rep = fn()
    finally:
        prof.disable()
    buf = io.StringIO()
    pstats.Stats(prof, stream=buf).sort_stats("cumulative").print_stats(20)
    print(buf.getvalue())
    rep.check("profile mode completed", True,
              f"top-20 cumulative for {name} (generic cProfile wrap)")
    return rep


def all_benchmarks() -> dict:
    return dict(_REGISTRY)


# shared simulator fixtures (scaled for CPU wall-time; rates match paper).
# Every shared sim runs with a TraceRecorder attached (bit-identical to an
# unrecorded run, regression-tested in tests/test_trace.py) so any figure
# benchmark can consume the trace via get_trace().
_SIM_CACHE: dict = {}
_TRACE_CACHE: dict = {}


def _sim_key(cluster, days, seed, kw):
    return (cluster, days, seed, json.dumps(kw, sort_keys=True, default=str))


def get_sim(cluster: str = "RSC-1", days: float = 8.0, seed: int = 0,
            **kw):
    """Scaled cluster sim: node count /5, rates preserved."""
    from repro.cluster.scheduler import ClusterSim
    from repro.cluster.workload import RSC1, RSC2
    from repro.trace import TraceRecorder
    import dataclasses

    key = _sim_key(cluster, days, seed, kw)
    if key in _SIM_CACHE:
        return _SIM_CACHE[key]
    spec0 = RSC1 if cluster == "RSC-1" else RSC2
    spec = dataclasses.replace(
        spec0, n_nodes=spec0.n_nodes // 5,
        jobs_per_day=spec0.jobs_per_day / 5)
    sim = ClusterSim(spec, horizon_days=days, seed=seed,
                     recorder=TraceRecorder(), **kw)
    sim.run()
    _SIM_CACHE[key] = sim
    return sim


def get_trace(cluster: str = "RSC-1", days: float = 8.0, seed: int = 0,
              **kw):
    """The shared sim's recorded trace (record once, analyze many)."""
    key = _sim_key(cluster, days, seed, kw)
    if key not in _TRACE_CACHE:
        sim = get_sim(cluster, days, seed, **kw)
        _TRACE_CACHE[key] = sim.recorder.finalize(sim)
    return _TRACE_CACHE[key]
